"""Migration planner/executor — FedFly's Steps 6-9 (Fig. 2).

``MigrationExecutor.migrate`` takes the source edge's ``EdgeCheckpoint``,
packs it (raw or int8-delta codec), moves the bytes, and unpacks at the
destination. Byte movement goes through one of:

  direct        — edge→edge (paper default: "the source edge server
                  transfers data directly to the destination edge server")
  device_relay  — edge→device→edge (paper fallback: "the device can then
                  transfer the checkpointed data between edge servers"
                  when edges cannot talk to each other); costs two link
                  traversals on the simulated clock.
  transport     — an actual byte channel (TCP socket / in-proc queue) when
                  the caller wires one in; wall-clock timed.

Codecs: ``raw`` (bit-exact), ``int8`` (per-leaf quantization), and
``delta`` — int8 residuals against the newest base version the
destination edge has synced (``BaseVersionRegistry``); an edge holding
the round-k broadcast receives only the drift since round k. A
``stream_send`` hook switches packing to the chunked pipeline
(``pack_chunks`` → ``FrameStream.send_chunked``): serialization overlaps
the socket transfer instead of completing before the first byte moves.

Every migration returns a ``MigrationReport`` with real wall-clock pack/
transfer/unpack times *and* the simulated-testbed transfer time from the
link model (75 Mbps Wi-Fi by default) — the quantity the paper's "≤2 s
overhead" claim refers to.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, Optional

import jax
import numpy as np

from repro.core.checkpoint import EdgeCheckpoint
from repro.obs import telemetry as obs
from repro.runtime import serialization
from repro.runtime.checkpoint_manager import BaseVersionRegistry
from repro.runtime.transport import LinkModel

Params = Any


@dataclass
class MigrationReport:
    client_id: str
    src_edge: str
    dst_edge: str
    nbytes: int
    codec: str
    route: str                 # "direct" | "device_relay"
    pack_s: float
    transfer_s: float          # wall clock (0 if no real transport)
    unpack_s: float
    sim_transfer_s: float      # link-model time (the paper's overhead)
    quant_error: float = 0.0   # max abs param error introduced by codec
    base_version: Optional[str] = None   # delta: base the payload rides on
    overlapped: bool = False   # pack streamed into the transfer

    @property
    def wall_total_s(self) -> float:
        return self.pack_s + self.transfer_s + self.unpack_s

    @property
    def sim_total_s(self) -> float:
        return self.pack_s + self.sim_transfer_s + self.unpack_s


class MigrationExecutor:
    """Moves one device's server-stage training state between edges."""

    def __init__(self, link: LinkModel = LinkModel(), codec: str = "raw",
                 send: Optional[Callable[[str, bytes], None]] = None,
                 recv: Optional[Callable[[str], bytes]] = None,
                 base_registry: Optional[BaseVersionRegistry] = None,
                 stream_send: Optional[Callable[[str, Iterable[bytes]],
                                                int]] = None):
        self.link = link
        self.codec = codec
        self._send = send
        self._recv = recv
        self._stream_send = stream_send
        self.base_registry = base_registry
        self.reports: list[MigrationReport] = []

    def migrate(self, ckpt: EdgeCheckpoint, src_edge: str, dst_edge: str,
                route: str = "direct", *, base: Params = None,
                base_version: Optional[str] = None
                ) -> tuple[EdgeCheckpoint, MigrationReport]:
        if (self.codec == "delta" and base is None
                and self.base_registry is not None):
            base, base_version = self.base_registry.base_for(dst_edge)

        overlapped = self._stream_send is not None and self._recv is not None
        t0 = time.perf_counter()
        if overlapped:
            # chunked pipeline: serialization overlaps the socket send,
            # so there is no separate pack phase to clock
            with obs.span("mig.transfer", client=ckpt.client_id,
                          codec=self.codec, overlapped=True):
                nbytes = self._stream_send(
                    dst_edge, ckpt.pack_chunks(self.codec, base=base,
                                               base_version=base_version))
                t1 = time.perf_counter()
                payload_rx = self._recv(dst_edge)
        else:
            with obs.span("mig.pack", client=ckpt.client_id,
                          codec=self.codec):
                payload = ckpt.pack(self.codec, base=base,
                                    base_version=base_version)
            nbytes = len(payload)
            t1 = time.perf_counter()
            if self._send is not None and self._recv is not None:
                with obs.span("mig.transfer", client=ckpt.client_id,
                              nbytes=nbytes):
                    self._send(dst_edge, payload)
                    payload_rx = self._recv(dst_edge)
            else:
                payload_rx = payload
        t2 = time.perf_counter()

        with obs.span("mig.unpack", client=ckpt.client_id):
            restored = EdgeCheckpoint.unpack(payload_rx, base=base)
        t3 = time.perf_counter()

        hops = 2 if route == "device_relay" else 1
        sim_transfer = hops * self.link.transfer_time(nbytes)

        qerr = 0.0
        if self.codec != "raw":
            orig = jax.tree.leaves(jax.tree.map(np.asarray, ckpt.server_params))
            rest = jax.tree.leaves(restored.server_params)
            qerr = max(((float(np.max(np.abs(np.asarray(a, np.float32)
                                             - np.asarray(b, np.float32))))
                         if a.size else 0.0) for a, b in zip(orig, rest)),
                       default=0.0)   # empty server-param pytree → no error

        report = MigrationReport(
            client_id=ckpt.client_id, src_edge=src_edge, dst_edge=dst_edge,
            nbytes=nbytes, codec=self.codec, route=route,
            # overlapped: pack rode inside the transfer, clock it there
            pack_s=0.0 if overlapped else t1 - t0,
            transfer_s=t2 - (t0 if overlapped else t1), unpack_s=t3 - t2,
            sim_transfer_s=sim_transfer, quant_error=qerr,
            base_version=base_version, overlapped=overlapped)
        self.reports.append(report)
        return restored, report

    def total_overhead_s(self) -> float:
        return sum(r.sim_total_s for r in self.reports)
