#!/usr/bin/env python3
"""Fail on broken intra-repo links in Markdown docs.

Scans every ``*.md`` under the repo (skipping .git and caches) for
inline links/images ``[text](target)``, resolves relative targets
against the containing file, and exits 1 listing any target that does
not exist. External links (``http(s)://``, ``mailto:``) and pure
fragments (``#...``) are ignored; a ``path#fragment`` target is checked
for the path only.

  python scripts/check_doc_links.py [root]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# inline [text](target) — target up to the first unescaped ')'; markdown
# reference-style links are not used in this repo
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "node_modules"}
_EXTERNAL = ("http://", "https://", "mailto:")


def iter_md_files(root: Path):
    for path in sorted(root.rglob("*.md")):
        if not _SKIP_DIRS.intersection(p.name for p in path.parents):
            yield path


def broken_links(root: Path) -> list:
    """[(md_file, raw_target), ...] for every unresolvable link."""
    bad = []
    for md in iter_md_files(root):
        for raw in _LINK.findall(md.read_text(encoding="utf-8")):
            if raw.startswith(_EXTERNAL) or raw.startswith("#"):
                continue
            target = raw.split("#", 1)[0]
            if not target:
                continue
            if not (md.parent / target).exists():
                bad.append((md.relative_to(root), raw))
    return bad


def main(argv=None) -> int:
    root = Path(argv[1] if argv and len(argv) > 1
                else Path(__file__).resolve().parent.parent)
    bad = broken_links(root)
    for md, raw in bad:
        print(f"BROKEN LINK  {md}: ({raw})")
    if bad:
        print(f"{len(bad)} broken intra-repo link(s)")
        return 1
    n = sum(1 for _ in iter_md_files(root))
    print(f"docs link check OK ({n} markdown files)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
