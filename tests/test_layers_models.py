"""Layer-level unit tests: blocked vs dense attention, MoE vs dense
reference, SSM/RWKV cell-vs-scan consistency, norms, RoPE, chunked xent,
optimizers vs numpy, schedules, data pipeline properties."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.datasets import synthetic_cifar10, synthetic_tokens
from repro.data.loader import Batcher
from repro.data.partition import balanced, by_fraction, dirichlet
from repro.models import layers, moe as moe_lib, ssm as ssm_lib
from repro.models.registry import get_config, make_reduced
from repro.optim import optimizers as opt_lib
from repro.optim import schedules


# -- attention ---------------------------------------------------------------

def test_blocked_attention_equals_dense():
    cfg = make_reduced(get_config("gemma2-9b"))
    p = layers.attention_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 64
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    for win in (0, 8):
        ref = layers.attention(p, cfg, x, positions=pos,
                               window=jnp.int32(win))
        old_t, old_b = layers.BLOCKED_ATTN_THRESHOLD, layers.BLOCK_KV
        layers.BLOCKED_ATTN_THRESHOLD, layers.BLOCK_KV = 16, 16
        try:
            blk = layers.attention(p, cfg, x, positions=pos,
                                   window=jnp.int32(win))
        finally:
            layers.BLOCKED_ATTN_THRESHOLD, layers.BLOCK_KV = old_t, old_b
        np.testing.assert_allclose(np.asarray(ref), np.asarray(blk),
                                   atol=2e-5)


def test_decode_attention_matches_full():
    """Decoding position t with a cache filled from a full forward must
    equal full attention's row t."""
    cfg = make_reduced(get_config("yi-6b"))
    p = layers.attention_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 1, 8
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    full = layers.attention(p, cfg, x, positions=pos, window=jnp.int32(0))

    C = S
    ck = jnp.zeros((B, C, cfg.num_kv_heads, cfg.head_dim))
    cv = jnp.zeros_like(ck)
    cpos = jnp.full((B, C), -1, jnp.int32)
    out = None
    for t in range(S):
        out, ck, cv, cpos = layers.decode_attention(
            p, cfg, x[:, t:t + 1], pos=jnp.int32(t), cache_k=ck,
            cache_v=cv, cache_positions=cpos, window=jnp.int32(0))
    np.testing.assert_allclose(np.asarray(out[:, 0]),
                               np.asarray(full[:, -1]), atol=1e-4)


def test_sliding_window_masks_old_tokens():
    cfg = make_reduced(get_config("yi-6b"))
    p = layers.attention_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S, W = 1, 16, 4
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    full = layers.attention(p, cfg, x, positions=pos, window=jnp.int32(W))
    # perturbing a token outside every query's window must not change
    # the last query's output
    x2 = x.at[:, 0].add(100.0)
    full2 = layers.attention(p, cfg, x2, positions=pos, window=jnp.int32(W))
    np.testing.assert_allclose(np.asarray(full[:, -1]),
                               np.asarray(full2[:, -1]), atol=1e-4)


# -- MoE ----------------------------------------------------------------------

def test_moe_matches_dense_at_high_capacity():
    cfg = make_reduced(get_config("grok-1-314b")).replace(capacity_factor=8.0)
    p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y = moe_lib.moe(p, cfg, x)
    gates = jax.nn.softmax((x @ p["router"]).astype(jnp.float32), -1)
    topv, topi = jax.lax.top_k(gates, cfg.num_experts_per_tok)
    topv = topv / topv.sum(-1, keepdims=True)
    ref = jnp.zeros_like(y)
    for b in range(2):
        for s in range(8):
            acc = jnp.zeros((cfg.d_model,))
            for kk in range(cfg.num_experts_per_tok):
                e = int(topi[b, s, kk])
                h = jax.nn.silu(x[b, s] @ p["wi_gate"][e]) \
                    * (x[b, s] @ p["wi_up"][e])
                acc += topv[b, s, kk] * (h @ p["wo"][e])
            ref = ref.at[b, s].set(acc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)


def test_moe_capacity_drops_tokens_not_nan():
    cfg = make_reduced(get_config("grok-1-314b")).replace(
        capacity_factor=0.1)
    p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y = moe_lib.moe(p, cfg, x)
    assert bool(jnp.isfinite(y).all())


def test_load_balance_loss_bounds():
    cfg = make_reduced(get_config("arctic-480b"))
    p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    lb = float(moe_lib.load_balance_loss(p, cfg, x))
    assert lb >= 1.0 - 1e-3   # E * sum(f_e p_e) >= 1 w/ equality at uniform


# -- SSM / RWKV ----------------------------------------------------------------

def test_mamba_cell_matches_scan():
    cfg = make_reduced(get_config("hymba-1.5b"))
    p = ssm_lib.mamba_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    ys, hT = ssm_lib.mamba_scan(p, cfg, x)
    h = jnp.zeros((B, cfg.d_model, cfg.ssm_state))
    for t in range(S):
        h, y = ssm_lib.mamba_cell(p, h, x[:, t])
        np.testing.assert_allclose(np.asarray(y), np.asarray(ys[:, t]),
                                   atol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hT), atol=1e-5)


def test_rwkv_cell_matches_scan():
    cfg = make_reduced(get_config("rwkv6-1.6b"))
    p = ssm_lib.rwkv_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    ys, (sT, xlast) = ssm_lib.rwkv_scan(p, cfg, x)
    H = cfg.d_model // ssm_lib.RWKV_HEAD
    state = jnp.zeros((B, H, ssm_lib.RWKV_HEAD, ssm_lib.RWKV_HEAD))
    xprev = jnp.zeros((B, cfg.d_model))
    for t in range(S):
        state, y = ssm_lib.rwkv_cell(p, cfg, state, x[:, t], xprev)
        xprev = x[:, t]
        np.testing.assert_allclose(np.asarray(y), np.asarray(ys[:, t]),
                                   atol=1e-5)
    np.testing.assert_allclose(np.asarray(state), np.asarray(sT), atol=1e-5)


def test_rwkv_decay_in_unit_interval():
    cfg = make_reduced(get_config("rwkv6-1.6b"))
    p = ssm_lib.rwkv_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    xw = jax.random.normal(jax.random.PRNGKey(1), (4, cfg.d_model)) * 3
    w = ssm_lib.rwkv_decay(p, xw)
    assert bool(((w > 0) & (w < 1)).all())


# -- norms / rope / xent --------------------------------------------------------

def test_rmsnorm_unit_scale():
    p = layers.rmsnorm_init(16, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16)) * 7
    y = layers.rmsnorm(p, x)
    rms = jnp.sqrt(jnp.mean(jnp.square(y), -1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, atol=1e-3)


def test_rope_preserves_norm_and_relative():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 64))
    pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (1, 8))
    y = layers.rope(x, pos, 10_000.0)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               atol=1e-4)
    # relative property: <rope(q,m), rope(k,n)> depends only on m-n
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 64))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 64))
    def dot_at(m, n):
        qm = layers.rope(q, jnp.full((1, 1), m, jnp.int32), 1e4)
        kn = layers.rope(k, jnp.full((1, 1), n, jnp.int32), 1e4)
        return float(jnp.sum(qm * kn))
    assert dot_at(3, 1) == pytest.approx(dot_at(7, 5), abs=1e-3)


def test_chunked_xent_equals_dense(reduced_models):
    cfg, model, params = reduced_models("qwen3-0.6b")
    from conftest import batch_for
    batch = batch_for(cfg, 2, 16)
    dense = model.loss(params, batch)
    old_thr, old_c = model.XENT_CHUNK_THRESHOLD, model.XENT_CHUNK
    type(model).XENT_CHUNK_THRESHOLD, type(model).XENT_CHUNK = 1, 4
    try:
        chunked = model.loss(params, batch)
    finally:
        type(model).XENT_CHUNK_THRESHOLD = old_thr
        type(model).XENT_CHUNK = old_c
    assert abs(float(dense - chunked)) < 2e-6


# -- optimizers ------------------------------------------------------------------

def test_sgd_matches_numpy():
    opt = opt_lib.sgd(momentum=0.9)
    p = {"w": jnp.asarray([1.0, 2.0])}
    s = opt.init(p)
    g = {"w": jnp.asarray([0.1, -0.2])}
    p1, s1 = opt.update(g, s, p, jnp.float32(0.5))
    np.testing.assert_allclose(np.asarray(p1["w"]), [0.95, 2.1], atol=1e-6)
    p2, _ = opt.update(g, s1, p1, jnp.float32(0.5))
    mu2 = 0.9 * np.array([0.1, -0.2]) + np.array([0.1, -0.2])
    np.testing.assert_allclose(np.asarray(p2["w"]),
                               np.asarray(p1["w"]) - 0.5 * mu2, atol=1e-6)


def test_adamw_first_step_size():
    opt = opt_lib.adamw(weight_decay=0.0)
    p = {"w": jnp.asarray([0.0])}
    s = opt.init(p)
    g = {"w": jnp.asarray([1e-3])}
    p1, _ = opt.update(g, s, p, jnp.float32(1e-2))
    # bias-corrected first step ≈ -lr * sign(g)
    assert float(p1["w"][0]) == pytest.approx(-1e-2, rel=1e-3)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0)}
    clipped, norm = opt_lib.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(6.0)
    assert float(opt_lib.global_norm(clipped)) == pytest.approx(1.0,
                                                                rel=1e-5)


def test_wsd_schedule_shape():
    f = schedules.wsd(1.0, 1000)
    assert float(f(0)) == pytest.approx(0.0, abs=1e-6)
    assert float(f(500)) == pytest.approx(1.0)
    assert float(f(999)) < 0.2
    c = schedules.cosine(1.0, 100, warmup_steps=10)
    assert float(c(5)) == pytest.approx(0.5)
    assert float(c(100)) == pytest.approx(0.1, rel=1e-2)


# -- data -------------------------------------------------------------------------

def test_partitions_disjoint_and_sized():
    train, _ = synthetic_cifar10(n_train=1000, n_test=10)
    parts = by_fraction(train, [0.25, 0.25, 0.25, 0.25])
    assert [len(p) for p in parts] == [250] * 4
    parts2 = dirichlet(train, 4, alpha=0.5)
    assert sum(len(p) for p in parts2) == 1000


def test_batcher_resume_determinism():
    """Load-bearing for migration: batch_at(epoch, i) must be a pure
    function so the destination edge replays the exact batch stream."""
    train, _ = synthetic_cifar10(n_train=500, n_test=10)
    b1 = Batcher(train, 50, seed=3)
    b2 = Batcher(train, 50, seed=3)
    x1 = b1.batch_at(2, 3)
    x2 = b2.batch_at(2, 3)
    np.testing.assert_array_equal(x1["images"], x2["images"])
    # different epochs shuffle differently
    x3 = b1.batch_at(3, 3)
    assert not np.array_equal(x1["labels"], x3["labels"])


def test_synthetic_cifar_learnable():
    """Linear probe beats chance by a wide margin -> accuracy experiments
    are meaningful."""
    train, test = synthetic_cifar10(n_train=2000, n_test=500, seed=1)
    X = train.images.reshape(len(train), -1)
    Xt = test.images.reshape(len(test), -1)
    Y = np.eye(10)[train.labels]
    W = np.linalg.lstsq(X.T @ X + 1e2 * np.eye(X.shape[1]), X.T @ Y,
                        rcond=None)[0]
    acc = (np.argmax(Xt @ W, 1) == test.labels).mean()
    assert acc > 0.45


def test_synthetic_tokens_structured():
    d = synthetic_tokens(4, 256, 1000, seed=0)
    follows = (d["tokens"][:, 1:] == (d["tokens"][:, :-1] + 1) % 1000).mean()
    assert 0.2 < follows < 0.7


def test_rwkv_chunked_matches_sequential():
    """The chunk-parallel closed form (§Perf hillclimb) must match the
    sequential WKV6 scan exactly, values and gradients."""
    import jax
    import jax.numpy as jnp
    cfg = make_reduced(get_config("rwkv6-1.6b"))
    p = ssm_lib.rwkv_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 96, cfg.d_model))
    y1, (s1, _) = ssm_lib.rwkv_scan(p, cfg, x)
    for chunk in (16, 32, 96):
        y2, (s2, _) = ssm_lib.rwkv_scan_chunked(p, cfg, x, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   atol=1e-4)
    g1 = jax.grad(lambda a: ssm_lib.rwkv_scan(p, cfg, a)[0].sum())(x)
    g2 = jax.grad(lambda a: ssm_lib.rwkv_scan_chunked(p, cfg, a,
                                                      chunk=32)[0].sum())(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)


def test_rwkv_chunked_state_continuation():
    """Chunked scan with a carried-in state (mid-sequence migration of an
    SSM arch) must continue exactly."""
    import jax
    import jax.numpy as jnp
    cfg = make_reduced(get_config("rwkv6-1.6b"))
    p = ssm_lib.rwkv_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
    y_full, (s_full, _) = ssm_lib.rwkv_scan_chunked(p, cfg, x, chunk=16)
    _, (s_half, _) = ssm_lib.rwkv_scan_chunked(p, cfg, x[:, :32], chunk=16)
    y2, (s2, _) = ssm_lib.rwkv_scan_chunked(
        p, cfg, x[:, 32:], state0=s_half, xprev0=x[:, 31], chunk=16)
    np.testing.assert_allclose(np.asarray(y_full[:, 32:]), np.asarray(y2),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2),
                               atol=1e-4)


def test_mamba_chunked_matches_sequential():
    """Chunk-parallel selective scan (§Perf bonus hillclimb) vs the
    sequential scan: values, final state, gradients."""
    import jax
    import jax.numpy as jnp
    cfg = make_reduced(get_config("hymba-1.5b"))
    p = ssm_lib.mamba_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    y1, h1 = ssm_lib.mamba_scan(p, cfg, x)
    for chunk in (16, 32):
        y2, h2 = ssm_lib.mamba_scan_chunked(p, cfg, x, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                                   atol=1e-4)
    g1 = jax.grad(lambda a: ssm_lib.mamba_scan(p, cfg, a)[0].sum())(x)
    g2 = jax.grad(lambda a: ssm_lib.mamba_scan_chunked(
        p, cfg, a, chunk=16)[0].sum())(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)
