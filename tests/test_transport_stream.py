"""SocketTransport sustained streams: many length-prefixed frames per
TCP connection (edge-to-edge migration streams), and chunked frames
whose production overlaps the socket transfer."""
from __future__ import annotations

import socket
import threading
import time

import numpy as np

from repro.core.checkpoint import EdgeCheckpoint
from repro.runtime import serialization as ser
from repro.runtime.transport import SocketTransport


def test_many_frames_one_connection():
    srv = SocketTransport().serve()
    try:
        frames = [bytes([i]) * (100 + i) for i in range(5)]
        with srv.connect("127.0.0.1", srv.port) as stream:
            for f in frames:
                stream.send(f)
        got = [srv.recv(timeout=10) for _ in frames]
        assert got == frames
    finally:
        srv.close()


def test_large_frame_then_small():
    srv = SocketTransport().serve()
    try:
        big = np.random.default_rng(0).bytes(1 << 20)
        with srv.connect("127.0.0.1", srv.port) as stream:
            stream.send(big)
            stream.send(b"tail")
        assert srv.recv(timeout=10) == big
        assert srv.recv(timeout=10) == b"tail"
    finally:
        srv.close()


def test_sequential_connections_still_served():
    """Old one-frame-per-connection clients (send_to) keep working, and
    the listener survives connection after connection. Ordering is only
    guaranteed within a connection, so compare as a set."""
    srv = SocketTransport().serve()
    try:
        for i in range(3):
            srv.send_to("127.0.0.1", srv.port, f"msg-{i}".encode())
        assert {srv.recv(timeout=10) for _ in range(3)} == \
            {b"msg-0", b"msg-1", b"msg-2"}
        with srv.connect("127.0.0.1", srv.port) as stream:
            stream.send(b"streamed")
        assert srv.recv(timeout=10) == b"streamed"
    finally:
        srv.close()


def test_open_stream_does_not_starve_other_senders():
    """A long-lived idle FrameStream must not block other connections
    (thread-per-connection listener)."""
    srv = SocketTransport().serve()
    try:
        with srv.connect("127.0.0.1", srv.port) as idle:
            idle.send(b"from-idle-stream")
            srv.send_to("127.0.0.1", srv.port, b"from-send-to")
            got = {srv.recv(timeout=10), srv.recv(timeout=10)}
            assert got == {b"from-idle-stream", b"from-send-to"}
    finally:
        srv.close()


def test_chunked_frame_reassembled():
    """A chunked frame (unknown total size up front) is delivered as ONE
    payload, byte-identical to the concatenated chunks."""
    srv = SocketTransport().serve()
    try:
        big = np.random.default_rng(1).bytes(3 << 20)
        chunks = [big[i:i + 700_000] for i in range(0, len(big), 700_000)]
        with srv.connect("127.0.0.1", srv.port) as s:
            assert s.send_chunked(iter(chunks)) == len(big)
        assert srv.recv(timeout=10) == big
    finally:
        srv.close()


def test_chunked_and_plain_frames_interleave_on_one_connection():
    """Mid-stream connection reuse: plain / chunked / plain / chunked on
    a single FrameStream, all delivered in order."""
    srv = SocketTransport().serve()
    try:
        with srv.connect("127.0.0.1", srv.port) as s:
            s.send(b"plain-1")
            s.send_chunked(iter([b"a" * 1000, b"b" * 1000]))
            s.send(b"plain-2")
            s.send_chunked(iter([b"", b"c" * 10]))   # empty chunks skipped
        assert srv.recv(10) == b"plain-1"
        assert srv.recv(10) == b"a" * 1000 + b"b" * 1000
        assert srv.recv(10) == b"plain-2"
        assert srv.recv(10) == b"c" * 10
    finally:
        srv.close()


def test_many_chunked_frames_back_to_back():
    srv = SocketTransport().serve()
    try:
        payloads = [bytes([i]) * (50_000 + i) for i in range(8)]
        with srv.connect("127.0.0.1", srv.port) as s:
            for p in payloads:
                s.send_chunked(p[i:i + 9973] for i in range(0, len(p), 9973))
        for p in payloads:
            assert srv.recv(timeout=10) == p
    finally:
        srv.close()


def test_chunked_survives_slow_consumer():
    """Backpressure correctness: the receiver drains slowly (TCP window
    fills, sendall blocks, the bounded producer queue fills) — the
    payload must still arrive intact."""
    srv = SocketTransport()
    orig_recv = srv._recv_frames

    class SlowConn:
        """Throttles the server's recv loop to ~6 MB/s."""

        def __init__(self, conn):
            self._c = conn

        def recv(self, n):
            time.sleep(0.005)
            return self._c.recv(min(n, 32768))

        def settimeout(self, t):
            self._c.settimeout(t)

    srv._recv_frames = lambda conn, deliver: orig_recv(SlowConn(conn),
                                                       deliver)
    srv.serve()
    try:
        big = np.random.default_rng(2).bytes(2 << 20)

        def gen():
            for i in range(0, len(big), 65536):
                yield big[i:i + 65536]
        with srv.connect("127.0.0.1", srv.port) as s:
            sent = s.send_chunked(gen())
        assert sent == len(big)
        assert srv.recv(timeout=30) == big
    finally:
        srv.close()


def test_chunked_send_overlaps_production():
    """The first bytes must hit the wire while later chunks are still
    being produced — the serialize-then-send barrier is gone. A raw
    socket server records when the first byte arrives; a slow producer
    records when the last chunk is generated."""
    lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    lsock.bind(("127.0.0.1", 0))
    lsock.listen(1)
    port = lsock.getsockname()[1]
    first_byte_t = []
    done = threading.Event()

    def server():
        conn, _ = lsock.accept()
        with conn:
            total = 0
            while True:
                b = conn.recv(1 << 16)
                if not b:
                    break
                if not first_byte_t:
                    first_byte_t.append(time.perf_counter())
                total += len(b)
        done.set()

    th = threading.Thread(target=server, daemon=True)
    th.start()

    last_produced_t = []

    def slow_chunks():
        for i in range(12):
            time.sleep(0.02)
            yield bytes([i]) * 4096
        last_produced_t.append(time.perf_counter())

    from repro.runtime.transport import FrameStream
    with FrameStream("127.0.0.1", port) as s:
        s.send_chunked(slow_chunks())
    done.wait(timeout=10)
    lsock.close()
    assert first_byte_t and last_produced_t
    # first byte arrived long before production finished (~0.24s total)
    assert first_byte_t[0] < last_produced_t[0] - 0.05


def test_chunked_producer_error_aborts_frame():
    """A chunk iterator that raises mid-stream must NOT terminate the
    frame (the receiver would deliver a truncated payload as complete):
    the connection aborts, the peer drops the partial, and frames from
    other connections keep flowing."""
    srv = SocketTransport().serve()
    try:
        def bad_chunks():
            yield b"x" * 1000
            raise RuntimeError("producer died")

        stream = srv.connect("127.0.0.1", srv.port)
        try:
            with np.testing.assert_raises(RuntimeError):
                stream.send_chunked(bad_chunks())
        finally:
            stream.close()
        # the partial frame was dropped; the transport still serves
        srv.send_to("127.0.0.1", srv.port, b"still-alive")
        assert srv.recv(timeout=10) == b"still-alive"
    finally:
        srv.close()


def test_chunked_send_failure_unblocks_producer():
    """A peer that dies mid-transfer must not strand the producer thread
    blocked on the full queue (it would pin the payload forever)."""
    from repro.runtime.transport import FrameStream
    lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    lsock.bind(("127.0.0.1", 0))
    lsock.listen(1)

    def server():
        conn, _ = lsock.accept()
        conn.close()                       # reset mid-stream

    threading.Thread(target=server, daemon=True).start()
    fs = FrameStream("127.0.0.1", lsock.getsockname()[1])
    before = threading.active_count()

    def chunks():
        for _ in range(2000):              # 2000 x 64 KiB >> any buffer
            yield b"z" * 65536

    try:
        with np.testing.assert_raises(OSError):
            fs.send_chunked(chunks())
    finally:
        lsock.close()
    # the producer drained and exited rather than blocking on q.put
    deadline = time.time() + 5
    while threading.active_count() > before and time.time() < deadline:
        time.sleep(0.05)
    assert threading.active_count() <= before


def test_chunked_checkpoint_pipeline_roundtrip():
    """End to end: pack_pytree_chunks -> send_chunked -> reassembled
    container unpacks to the same tree."""
    srv = SocketTransport().serve()
    try:
        rng = np.random.default_rng(3)
        tree = {"w": rng.normal(size=(600, 50)).astype(np.float32),
                "i": np.arange(100, dtype=np.int64)}
        base = {"w": tree["w"] * 0.999}
        with srv.connect("127.0.0.1", srv.port) as s:
            s.send_chunked(ser.pack_pytree_chunks(
                tree, "delta", base=base, base_version="rt"))
        back = ser.unpack_pytree(srv.recv(timeout=10), base=base)
        np.testing.assert_array_equal(back["i"], tree["i"])
        assert np.abs(back["w"] - tree["w"]).max() <= \
            np.abs(tree["w"] * 0.001).max() / 127 * 0.51 + 1e-7
    finally:
        srv.close()


def test_per_connection_on_close_fires_even_if_deliver_raises():
    """A per-connection deliver callback that raises (bad frame, buggy
    consumer) must still fire on_close with the error — a mailbox
    waiting on that connection would otherwise hang until its barrier
    timeout instead of aborting."""
    closes = []

    def hooks():
        def deliver(b):
            raise ValueError("poisoned frame")

        def on_close(err):
            closes.append(err)
        return deliver, on_close

    srv = SocketTransport()
    srv.serve(per_connection=hooks)
    try:
        with srv.connect("127.0.0.1", srv.port) as s:
            s.send(b"boom")
        deadline = time.time() + 10
        while not closes and time.time() < deadline:
            time.sleep(0.05)
        assert len(closes) == 1 and isinstance(closes[0], ValueError)
    finally:
        srv.close()


def test_checkpoint_stream_roundtrip():
    """A sustained migration stream: several EdgeCheckpoints back to back
    on one connection, all unpacked intact."""
    srv = SocketTransport().serve()
    try:
        cks = [EdgeCheckpoint(
            client_id=f"dev-{i}", round_idx=i, epoch=0, batch_idx=i,
            split_point=2,
            server_params={"w": np.full((32, 32), float(i), np.float32)},
            optimizer_state={"mu": np.zeros((32, 32), np.float32)})
            for i in range(4)]
        with srv.connect("127.0.0.1", srv.port) as stream:
            for ck in cks:
                stream.send(ck.pack())
        for ck in cks:
            back = EdgeCheckpoint.unpack(srv.recv(timeout=10))
            assert back.client_id == ck.client_id
            np.testing.assert_array_equal(back.server_params["w"],
                                          ck.server_params["w"])
    finally:
        srv.close()
