"""Shard mailboxes: the transport under the conservative-window barrier.

The sharded engines (repro.sim.engine) synchronize by an all-to-all
exchange: every window, every participant sends ``(advertised_time,
outgoing Mail)`` to every peer and receives the same — the exchange IS
the barrier, and the global minimum over advertised times is the next
window start ``T``. This module abstracts *what carries that exchange*:

  ``PipeMailbox``    — multiprocessing pipes between worker processes on
                       one machine (what ``PeerShardedEngine`` uses).
  ``SocketMailbox``  — real TCP: one ``runtime.transport.FrameStream``
                       per directed peer pair, frames carrying
                       FFLY-encoded messages. The same protocol runs
                       across machines (``examples/fleet_sim_multihost``).

``run_host_windows`` is the host loop both transports drive: it owns a
*group* of ``EdgeShard`` engines (a "host"), runs their windows between
exchanges, routes intra-group mail locally, and ships simulator records
to the coordinator. ``HostShardedEngine`` packages N such hosts as
independent OS processes on one machine, connected only by sockets —
the localhost harness for the multi-host protocol (used by
``FleetSimulator(hosts=N)`` and ``bench_fleet.py --hosts``).

Wire format (normative spec: docs/ARCHITECTURE.md): every message is one
transport frame whose payload is an FFLY v2 container of a tagged
pytree — ``encode_message``/``decode_message`` below. No pickle crosses
the network, so hosts of different ISAs interoperate, and the migrated
client timing state (``ShardClient``) rides the same container format as
the checkpoints themselves.

Failure semantics (mirrors the chunked-frame producer abort): a peer
that disconnects mid-window — a killed host process, a dropped link —
must abort the run with a clear error, never hang the barrier. The
transport reports per-connection closes; ``SocketMailbox.exchange``
raises as soon as a peer it still needs is gone, and the coordinator
raises when a host's record stream dies before its ``done``.
"""
from __future__ import annotations

import dataclasses
import multiprocessing as mp
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.runtime.serialization import pack_pytree, unpack_pytree
from repro.runtime.transport import FrameStream, SocketTransport
from repro.sim.engine import (EventKind, Mail, _check_mail_within_lookahead,
                              _merge_shard_stats)
from repro.sim.shard import ShardClient

_TAG = "__w"                      # tagged-node marker in the wire tree
_BARRIER_TIMEOUT_S = 600.0        # no progress for this long => stalled
_SHIP_EVERY_WINDOWS = 8           # record-shipment cadence (amortize frames)
_CONNECT_RETRY_S = 60.0           # peers may start at different times


# ---------------------------------------------------------------------------
# wire codec: Mail and protocol messages as FFLY containers
# ---------------------------------------------------------------------------

def _to_wire(obj: Any) -> Any:
    """Lower a protocol object to an FFLY-serializable pytree (dicts with
    string keys, lists/tuples, scalar/ndarray leaves). Python-only values
    become tagged dicts: ``{"__w": tag, ...}`` — see docs/ARCHITECTURE.md
    for the closed set of tags."""
    if obj is None:
        return {_TAG: "none"}
    if isinstance(obj, EventKind):
        return {_TAG: "kind", "v": obj.value}
    if isinstance(obj, Mail):
        return {_TAG: "mail", "dst": obj.dst_shard, "time": obj.time,
                "kind": obj.kind.value, "key": obj.key,
                "payload": _to_wire(obj.payload)}
    if isinstance(obj, ShardClient):
        fields = {f.name: getattr(obj, f.name)
                  for f in dataclasses.fields(ShardClient)}
        if fields.pop("batch_event") is not None:
            # clients only travel between batches; a live BATCH_DONE would
            # reference engine state that cannot cross a host boundary
            raise ValueError(f"client {obj.client_id} has a live batch "
                             "event and cannot be serialized")
        return {_TAG: "sc", "v": {k: _to_wire(v) for k, v in fields.items()}}
    if isinstance(obj, dict):
        if all(isinstance(k, str) for k in obj) and _TAG not in obj:
            return {k: _to_wire(v) for k, v in obj.items()}
        # non-string keys would be stringified by the container's JSON
        # header — carry keys and values as parallel lists instead
        return {_TAG: "map", "k": [_to_wire(k) for k in obj],
                "v": [_to_wire(v) for v in obj.values()]}
    if isinstance(obj, tuple):
        return tuple(_to_wire(x) for x in obj)
    if isinstance(obj, list):
        return [_to_wire(x) for x in obj]
    if isinstance(obj, (bool, int, float, str, bytes, np.ndarray,
                        np.generic)):
        return obj
    raise TypeError(f"cannot wire-encode {type(obj).__name__}: {obj!r}")


def _from_wire(obj: Any) -> Any:
    """Inverse of ``_to_wire`` over a decoded FFLY tree (where every
    scalar leaf comes back as a 0-d numpy array)."""
    if isinstance(obj, np.ndarray):
        return obj.item() if obj.ndim == 0 else obj
    if isinstance(obj, dict):
        if _TAG not in obj:
            return {k: _from_wire(v) for k, v in obj.items()}
        tag = _from_wire(obj[_TAG])
        if tag == "none":
            return None
        if tag == "kind":
            return EventKind(_from_wire(obj["v"]))
        if tag == "mail":
            return Mail(dst_shard=_from_wire(obj["dst"]),
                        time=_from_wire(obj["time"]),
                        kind=EventKind(_from_wire(obj["kind"])),
                        key=_from_wire(obj["key"]),
                        payload=_from_wire(obj["payload"]))
        if tag == "sc":
            return ShardClient(**{k: _from_wire(v)
                                  for k, v in obj["v"].items()})
        if tag == "map":
            return dict(zip((_from_wire(k) for k in obj["k"]),
                            (_from_wire(v) for v in obj["v"])))
        raise ValueError(f"unknown wire tag {tag!r}")
    if isinstance(obj, tuple):
        return tuple(_from_wire(x) for x in obj)
    if isinstance(obj, list):
        return [_from_wire(x) for x in obj]
    return obj


def encode_message(msg: Dict[str, Any]) -> bytes:
    """One protocol message -> one frame payload (an FFLY container)."""
    return pack_pytree(_to_wire(msg))


def decode_message(data: bytes) -> Dict[str, Any]:
    return _from_wire(unpack_pytree(data))


# ---------------------------------------------------------------------------
# the mailbox interface
# ---------------------------------------------------------------------------

class Mailbox:
    """One participant's endpoint of the all-to-all mail mesh.

    ``exchange`` implements the window barrier: send ``(my_time,
    outbox[p])`` to every peer, receive the same from every peer, return
    ``(min over all advertised times incl. our own, incoming mail)``.
    Every participant computes the same minimum, so the exchange doubles
    as the barrier — there is no separate synchronization primitive."""

    peer_ids: Sequence[int] = ()

    def exchange(self, my_time: float, outbox: Dict[int, List[Mail]]
                 ) -> Tuple[float, List[Mail]]:
        raise NotImplementedError

    def close(self) -> None:
        pass


class PipeMailbox(Mailbox):
    """The in-process/pipe mesh: one duplex ``multiprocessing.Pipe`` per
    peer pair (what ``PeerShardedEngine`` wires up). Mail travels as
    pickled objects — same-machine only."""

    def __init__(self, peers: Dict[int, Any]):
        self._peers = peers
        self.peer_ids = sorted(peers)

    def exchange(self, my_time, outbox):
        for p in self.peer_ids:                      # send to all ...
            self._peers[p].send((my_time, outbox.get(p, [])))
        times = [my_time]
        incoming: List[Mail] = []
        for p in self.peer_ids:                      # ... then drain all
            try:
                pt, mail = self._peers[p].recv()
            except EOFError:
                raise RuntimeError(
                    f"mailbox peer {p} disconnected mid-window (worker "
                    "process died?) — aborting run") from None
            times.append(pt)
            incoming.extend(mail)
        return min(times), incoming


class SocketMailbox(Mailbox):
    """TCP mesh endpoint built on ``SocketTransport``/``FrameStream``.

    Topology: every participant runs one listener; for each *directed*
    pair (i -> j) host i opens one sustained ``FrameStream`` to host j's
    listener and sends a hello frame, then exactly one mail frame per
    window — so per-peer frame queues stay aligned with the window
    sequence. The same listener also accepts ``records`` channels (host
    -> coordinator record shipments), exposed on ``self.records``.

    A peer connection that closes before the protocol finished marks the
    peer dead and wakes any blocked ``exchange``, which aborts the run
    with a clear error instead of hanging the barrier (the socket analog
    of the chunked-frame producer abort)."""

    def __init__(self, rank: int, host: str = "127.0.0.1", port: int = 0, *,
                 barrier_timeout_s: float = _BARRIER_TIMEOUT_S):
        self.rank = rank
        self.barrier_timeout_s = barrier_timeout_s
        self.peer_ids: List[int] = []
        self._streams: Dict[int, FrameStream] = {}
        self._inbox: Dict[int, "queue.Queue"] = {}
        self._dead: Dict[int, str] = {}
        self._lock = threading.Lock()
        self._closing = False
        #: (type, src_rank, message) tuples from "records" channels
        self.records: "queue.Queue[Tuple[str, int, Dict[str, Any]]]" = \
            queue.Queue()
        self.transport = SocketTransport(host, port)
        self.port = self.transport.port
        self.transport.serve(per_connection=self._connection)

    # -- incoming side ---------------------------------------------------

    def _inbox_for(self, rank: int) -> "queue.Queue":
        with self._lock:
            return self._inbox.setdefault(rank, queue.Queue())

    def _connection(self):
        """Per-connection router: the first frame must be a hello naming
        the sender and channel; later frames go to that peer's inbox
        (mail) or the shared records queue."""
        state: Dict[str, Any] = {"channel": None, "src": None}

        def deliver(frame: bytes) -> None:
            try:
                msg = decode_message(frame)
            except Exception as e:
                raise ConnectionError(f"undecodable frame: {e}") from e
            if state["channel"] is None:
                if msg.get("type") != "hello":
                    raise ConnectionError(
                        f"expected hello, got {msg.get('type')!r}")
                state["channel"] = msg["channel"]
                state["src"] = msg["src"]
                return
            if state["channel"] == "mail":
                self._inbox_for(state["src"]).put(msg)
            else:
                self.records.put((msg["type"], state["src"], msg))

        def on_close(err: Optional[BaseException]) -> None:
            if self._closing or state["channel"] is None:
                return
            why = str(err) if err else "connection closed"
            if state["channel"] == "mail":
                self._dead[state["src"]] = why
                self._inbox_for(state["src"]).put(None)   # wake the waiter
            else:
                self.records.put(("lost", state["src"], {"err": why}))

        return deliver, on_close

    # -- outgoing side ---------------------------------------------------

    def connect(self, addresses: Dict[int, Tuple[str, int]], *,
                retry_s: float = _CONNECT_RETRY_S) -> "SocketMailbox":
        """Open the outgoing half of the mesh: one stream + hello per
        peer in ``addresses`` (our own rank is skipped). Retries while
        peers are still starting up."""
        self.peer_ids = sorted(r for r in addresses if r != self.rank)
        for r in self.peer_ids:
            self._inbox_for(r)                   # exist before any hello
            self._streams[r] = _connect_retry(addresses[r], retry_s)
            self._streams[r].send(encode_message(
                {"type": "hello", "channel": "mail", "src": self.rank}))
        return self

    # -- the barrier ------------------------------------------------------

    def exchange(self, my_time, outbox):
        for p in self.peer_ids:
            try:
                self._streams[p].send(encode_message(
                    {"type": "mail", "time": my_time,
                     "mail": outbox.get(p, [])}))
            except OSError as e:
                raise RuntimeError(
                    f"mailbox peer {p} unreachable ({e}) — aborting run"
                ) from None
        times = [my_time]
        incoming: List[Mail] = []
        for p in self.peer_ids:
            msg = self._pop(p)
            times.append(msg["time"])
            incoming.extend(msg["mail"])
        return min(times), incoming

    def _pop(self, p: int) -> Dict[str, Any]:
        deadline = time.monotonic() + self.barrier_timeout_s
        q = self._inbox_for(p)
        while True:
            try:
                msg = q.get(timeout=0.2)
            except queue.Empty:
                if time.monotonic() >= deadline:
                    raise RuntimeError(
                        f"window barrier made no progress for "
                        f"{self.barrier_timeout_s}s waiting on host {p} "
                        "(peer stalled?)") from None
                continue
            if msg is None:       # the dead-peer sentinel (FIFO: any
                # frames delivered before the close drain first)
                raise RuntimeError(
                    f"mailbox peer {p} disconnected mid-window "
                    f"({self._dead.get(p, 'connection closed')}) — "
                    "aborting run (host process died?)")
            return msg

    def close(self) -> None:
        self._closing = True
        for s in self._streams.values():
            try:
                s.close()
            except OSError:
                pass
        self.transport.close()


def _connect_retry(addr: Tuple[str, int],
                   retry_s: float = _CONNECT_RETRY_S) -> FrameStream:
    deadline = time.monotonic() + retry_s
    while True:
        try:
            return FrameStream(addr[0], addr[1])
        except OSError:
            if time.monotonic() >= deadline:
                raise
            time.sleep(0.2)


# ---------------------------------------------------------------------------
# record sinks: how a host ships simulator records to the coordinator
# ---------------------------------------------------------------------------

class PipeRecordSink:
    """Record shipments over the worker's parent pipe (peer executor)."""

    def __init__(self, conn):
        self._conn = conn

    def records(self, bound: float, recs: Dict[str, list]) -> None:
        self._conn.send(("records", bound, recs))

    def frontier(self, bound: float) -> None:
        self._conn.send(("frontier", bound))

    def done(self, finals: Dict[int, Dict[str, Any]]) -> None:
        self._conn.send(("done", finals))

    def err(self, tb: str) -> None:
        self._conn.send(("err", tb))

    def close(self) -> None:
        self._conn.close()


class SocketRecordSink:
    """Record shipments as FFLY frames on a sustained stream to the
    coordinator's listener (the ``records`` channel)."""

    def __init__(self, addr: Tuple[str, int], rank: int, *,
                 retry_s: float = _CONNECT_RETRY_S):
        self._stream = _connect_retry(addr, retry_s)
        self._stream.send(encode_message(
            {"type": "hello", "channel": "records", "src": rank}))

    def records(self, bound, recs):
        self._stream.send(encode_message(
            {"type": "records", "bound": bound, "records": recs}))

    def frontier(self, bound):
        self._stream.send(encode_message(
            {"type": "frontier", "bound": bound}))

    def done(self, finals):
        self._stream.send(encode_message({"type": "done", "stats": finals}))

    def err(self, tb):
        self._stream.send(encode_message({"type": "err", "traceback": tb}))

    def close(self):
        self._stream.close()


# ---------------------------------------------------------------------------
# the host loop: a group of shards between exchanges
# ---------------------------------------------------------------------------

def run_host_windows(shards: Sequence[Any], mailbox: Mailbox,
                     lookahead: float, sink: Any,
                     owner_of_shard: Optional[Dict[int, int]] = None) -> int:
    """Drive a *group* of shard engines under the mail-exchange barrier.

    Per window: advertise ``min(own next event, undelivered outgoing
    mail)``; everyone computes the same ``T = min(all advertised)``; exit
    together at ``T = +inf``; otherwise deliver incoming mail, run every
    shard's events in ``[T, T + lookahead)``, route produced mail (intra-
    group locally, cross-group into next window's outbox). Records ship
    to ``sink`` every few windows tagged with the covered bound, so the
    coordinator replays strictly below the fleet-wide safe frontier.
    ``owner_of_shard`` maps a destination shard id to the peer that owns
    it (identity when every peer is a single shard). Returns the window
    count."""
    group = {s.shard_id: s for s in shards}
    owner = owner_of_shard or {}
    inf = float("inf")
    windows = 0
    acc: Dict[str, list] = {"contribs": [], "epoch_starts": [],
                            "migrations": []}

    def ship(bound: float) -> None:
        if any(acc.values()):
            sink.records(bound, {k: list(v) for k, v in acc.items()})
            for k in acc:
                acc[k] = []
        else:
            sink.frontier(bound)

    def peek_min() -> float:
        return min((inf if (t := s.peek()) is None else t
                    for s in group.values()), default=inf)

    def deliver(mail: List[Mail]) -> None:
        by_dst: Dict[int, List[Mail]] = {}
        for m in mail:
            by_dst.setdefault(m.dst_shard, []).append(m)
        for dst in sorted(by_dst):
            group[dst].deliver(by_dst[dst])

    outbox: Dict[int, List[Mail]] = {p: [] for p in mailbox.peer_ids}
    my_t = peek_min()
    while True:
        T, incoming = mailbox.exchange(my_t, outbox)
        outbox = {p: [] for p in mailbox.peer_ids}
        if T == inf:
            break
        if incoming:
            deliver(incoming)
        bound = T + lookahead
        local: List[Mail] = []
        mail_min = inf
        for sid in sorted(group):
            res = group[sid].run_window(bound, [])
            for k, v in res.records.items():
                acc[k].extend(v)
            for m in res.mail:
                _check_mail_within_lookahead(m, bound)
                if m.dst_shard in group:
                    local.append(m)       # delivered below => covered by
                else:                     # the next peek_min()
                    outbox.setdefault(owner.get(m.dst_shard, m.dst_shard),
                                      []).append(m)
                    mail_min = min(mail_min, m.time)
        if local:
            deliver(local)
        my_t = min(peek_min(), mail_min)
        windows += 1
        if windows % _SHIP_EVERY_WINDOWS == 0:
            ship(bound)
    ship(inf)
    finals = {}
    for sid in sorted(group):
        f = group[sid].final_stats()
        f["engine"]["windows"] = windows
        finals[sid] = f
    sink.done(finals)
    return windows


# ---------------------------------------------------------------------------
# multi-host execution: N shard-group processes connected only by sockets
# ---------------------------------------------------------------------------

def _host_proc_main(conn) -> None:
    """Entry point of one host process (localhost harness). Bootstrap
    rides the spawn pipe — (rank, shard group, owner map, lookahead,
    record address) in, bound mail port out, peer directory in — and
    every byte of the window protocol after that rides TCP."""
    import traceback
    sink = None
    mailbox = None
    try:
        rank, group, owner, lookahead, record_addr = conn.recv()
        mailbox = SocketMailbox(rank)
        conn.send(("port", mailbox.port))
        directory = conn.recv()
        sink = SocketRecordSink(record_addr, rank)
        mailbox.connect(directory)
        conn.send(("ready",))
        run_host_windows(group, mailbox, lookahead, sink, owner)
    except BaseException:
        tb = traceback.format_exc()
        try:
            if sink is not None:
                sink.err(tb)
            else:
                conn.send(("err", tb))
        except (BrokenPipeError, OSError):
            pass
    finally:
        if mailbox is not None:
            mailbox.close()
        if sink is not None:
            sink.close()
        conn.close()


def drain_host_records(records: "queue.Queue", num_hosts: int,
                       on_chunk: Callable[[Optional[float],
                                           Dict[int, Dict[str, list]]], None],
                       *, timeout_s: float = _BARRIER_TIMEOUT_S
                       ) -> Dict[int, Dict[str, Any]]:
    """Coordinator side of the record protocol: consume ``(type, src,
    msg)`` tuples from ``records`` (a ``SocketMailbox.records`` queue)
    until every host reported ``done``; call ``on_chunk`` exactly like
    ``PeerShardedEngine.run`` does. Raises if a host errors, dies (its
    record stream closes before ``done``), or the mesh stalls. Returns
    the per-shard final stats."""
    inf = float("inf")
    frontiers = {r: 0.0 for r in range(num_hosts)}
    done: set = set()
    finals: Dict[int, Dict[str, Any]] = {}
    replay_frontier = 0.0
    while len(done) < num_hosts:
        try:
            kind, src, msg = records.get(timeout=timeout_s)
        except queue.Empty:
            raise RuntimeError(
                f"multi-host mesh made no progress for {timeout_s}s "
                "(host stalled?)") from None
        if kind == "err":
            raise RuntimeError(f"shard host {src} failed:\n"
                               f"{msg['traceback']}")
        if kind == "lost":
            if src in done:
                continue          # clean close after its done message
            raise RuntimeError(
                f"shard host {src} died mid-run ({msg['err']})")
        if kind == "records":
            frontiers[src] = msg["bound"]
            on_chunk(None, {src: msg["records"]})
        elif kind == "frontier":
            frontiers[src] = msg["bound"]
        elif kind == "done":
            finals.update(msg["stats"])
            done.add(src)
            frontiers[src] = inf
        new_frontier = min(frontiers.values())
        if new_frontier > replay_frontier:
            replay_frontier = new_frontier
            on_chunk(replay_frontier, {})
    on_chunk(inf, {})
    return finals


def merge_host_finals(finals: Dict[int, Dict[str, Any]], *, wall_s: float,
                      num_shards: int, num_hosts: int) -> Dict[str, Any]:
    """Fold per-shard final stats from a multi-host run into one
    engine-stats dict (shared by ``HostShardedEngine.stats`` and
    ``FleetSimulator.run_multihost`` so the stats shape cannot
    diverge)."""
    windows = max((f["engine"].get("windows", 0) for f in finals.values()),
                  default=0)
    stats = _merge_shard_stats(finals, wall_s=wall_s, windows=windows,
                               num_shards=num_shards)
    stats["num_hosts"] = num_hosts
    return stats


class HostShardedEngine:
    """Multi-host executor: N OS processes, each owning a group of
    ``EdgeShard`` engines, connected **only by TCP sockets** — the
    localhost harness for the protocol that runs across machines. The
    window barrier rides the ``SocketMailbox`` all-to-all exchange
    exactly as ``PeerShardedEngine``'s rides its pipes, and the parent
    drains record frames from its own listener, so ``on_chunk`` sees the
    same contract (and the replay stays bit-identical to
    ``SerialExecutor`` for any host count)."""

    def __init__(self, shards: Sequence[Any], *, lookahead: float,
                 hosts: int):
        if lookahead is None or lookahead <= 0:
            raise ValueError("multi-host execution needs a positive "
                             "lookahead")
        shards = sorted(shards, key=lambda s: s.shard_id)
        self.num_hosts = max(1, min(hosts, len(shards)))
        self.shard_ids = [s.shard_id for s in shards]
        self.owner = {sid: sid % self.num_hosts for sid in self.shard_ids}
        # the parent's listener doubles as the record collector; it never
        # joins the mail mesh (no connect), so rank is out-of-band
        self._collector = SocketMailbox(-1)
        self._final: Dict[int, Dict[str, Any]] = {}
        self.windows = 0
        self.wall_s = 0.0
        ctx = mp.get_context("spawn")
        self._procs = []
        self._boots = []
        record_addr = ("127.0.0.1", self._collector.port)
        try:
            for rank in range(self.num_hosts):
                group = [s for s in shards
                         if self.owner[s.shard_id] == rank]
                parent, child = ctx.Pipe()
                proc = ctx.Process(target=_host_proc_main, args=(child,),
                                   daemon=True)
                proc.start()
                parent.send((rank, group, self.owner, lookahead,
                             record_addr))
                self._procs.append(proc)
                self._boots.append(parent)
            directory = {rank: ("127.0.0.1", self._boot_recv(rank)[1])
                         for rank in range(self.num_hosts)}
            for parent in self._boots:
                parent.send(directory)
            for rank in range(self.num_hosts):
                self._boot_recv(rank)             # ("ready",)
        except BaseException:
            # a failed bootstrap must not leak the collector listener or
            # the already-spawned host processes (the caller never gets
            # an engine to close)
            self.close()
            raise

    def _boot_recv(self, rank: int):
        conn = self._boots[rank]
        if not conn.poll(timeout=120):
            raise RuntimeError(f"shard host {rank} did not start "
                               "(bootstrap timeout)")
        try:
            msg = conn.recv()
        except EOFError:
            raise RuntimeError(
                f"shard host {rank} died during startup") from None
        if msg[0] == "err":
            raise RuntimeError(f"shard host {rank} failed during "
                               f"startup:\n{msg[1]}")
        return msg

    def run(self, on_chunk) -> "HostShardedEngine":
        wall0 = time.perf_counter()
        self._final = drain_host_records(self._collector.records,
                                         self.num_hosts, on_chunk)
        self.wall_s = time.perf_counter() - wall0
        return self

    def stats(self) -> Dict[str, Any]:
        out = merge_host_finals(self._final, wall_s=self.wall_s,
                                num_shards=len(self.shard_ids),
                                num_hosts=self.num_hosts)
        self.windows = out["windows"]
        return out

    def close(self) -> None:
        self._collector.close()
        for conn in self._boots:
            try:
                conn.close()
            except OSError:
                pass
        for proc in self._procs:
            proc.join(timeout=5)
            if proc.is_alive():
                proc.terminate()
