"""Serving-session migration — FedFly's mechanism applied to inference.

The paper migrates *training* state between edge servers. The same
checkpoint-transfer-resume protocol applies verbatim to a *decode
session*: when a device moves mid-generation, the source edge
checkpoints `{KV cache / recurrent state, position, last tokens}` and
the destination resumes decoding the next token bit-identically.

This is a beyond-paper extension, but it answers the paper's own
"communication overhead" future-work worry quantitatively: a 32k-deep
bf16 KV cache is orders of magnitude larger than the VGG-5 training
checkpoint, so the int8 codec and (for window/SSM archs) the
constant-size state are what keep session migration inside the 2 s
envelope.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.runtime import serialization

Params = Any


@dataclass
class ServeSession:
    """One device's decode session held by an edge server."""

    session_id: str
    cache: Params                 # model.init_cache pytree (KV / states)
    position: int                 # next decode position
    tokens_generated: int = 0
    meta: Dict[str, Any] = field(default_factory=dict)

    def to_tree(self) -> Dict[str, Any]:
        return {
            "scalars": {
                "session_id": np.frombuffer(
                    self.session_id.encode().ljust(64, b"\0")[:64],
                    np.uint8).copy(),
                "position": np.int64(self.position),
                "tokens_generated": np.int64(self.tokens_generated),
            },
            "cache": jax.tree.map(np.asarray, self.cache),
        }

    @classmethod
    def from_tree(cls, tree: Dict[str, Any]) -> "ServeSession":
        s = tree["scalars"]
        return cls(
            session_id=bytes(s["session_id"]).rstrip(b"\0").decode(),
            cache=tree["cache"],
            position=int(s["position"]),
            tokens_generated=int(s["tokens_generated"]))

    def pack(self, codec: str = "raw") -> bytes:
        return serialization.pack_pytree(self.to_tree(), codec=codec)

    @classmethod
    def unpack(cls, data: bytes) -> "ServeSession":
        return cls.from_tree(serialization.unpack_pytree(data))

    def nbytes(self, codec: str = "raw") -> int:
        return len(self.pack(codec))


def migrate_session(session: ServeSession, executor,
                    src_edge: str, dst_edge: str, route: str = "direct"):
    """Move a decode session between edges via the standard migration
    executor (reusing its link model, codec, and reporting). Returns the
    restored session (cache leaves as jnp arrays) and the report."""
    import jax.numpy as jnp

    from repro.core.checkpoint import EdgeCheckpoint

    ck = EdgeCheckpoint(
        client_id=session.session_id, round_idx=0, epoch=0,
        batch_idx=session.position, split_point=0,
        server_params=session.to_tree(), optimizer_state={},
        meta={"kind": "serve_session"})
    restored, report = executor.migrate(ck, src_edge, dst_edge, route=route)
    out = ServeSession.from_tree(restored.server_params)
    out.cache = jax.tree.map(jnp.asarray, out.cache)
    return out, report
