"""repro.sim.engine: event ordering, determinism, handler dispatch.

Ordering/bound tests are parametrized over both schedulers — the heap
reference and the calendar queue must be observationally identical
through the ``SimEngine`` API (the hypothesis stream test in
``test_scheduler_differential.py`` is the deeper version of this).
"""
from __future__ import annotations

import heapq
import random

import pytest

from repro.sim.engine import CalendarQueue, Event, EventKind, SimEngine, \
    make_queue

SCHEDULERS = ["heap", "calendar"]


def collect(engine, kinds=EventKind):
    seen = []
    for k in kinds:
        engine.register(k, lambda ev: seen.append(ev))
    return seen


@pytest.mark.parametrize("scheduler", SCHEDULERS)
def test_time_ordering(scheduler):
    eng = SimEngine(scheduler)
    seen = collect(eng)
    eng.schedule(3.0, EventKind.MOVE, tag="c")
    eng.schedule(1.0, EventKind.BATCH_DONE, tag="a")
    eng.schedule(2.0, EventKind.TRANSFER_DONE, tag="b")
    eng.run()
    assert [e.payload["tag"] for e in seen] == ["a", "b", "c"]
    assert eng.now == 3.0
    assert eng.events_processed == 3


@pytest.mark.parametrize("scheduler", SCHEDULERS)
def test_tie_break_is_insertion_order(scheduler):
    eng = SimEngine(scheduler)
    seen = collect(eng)
    for i in range(10):
        eng.schedule(1.0, EventKind.BATCH_DONE, i=i)
    eng.run()
    assert [e.payload["i"] for e in seen] == list(range(10))


@pytest.mark.parametrize("scheduler", SCHEDULERS)
def test_handlers_can_schedule(scheduler):
    eng = SimEngine(scheduler)
    fired = []

    def on_batch(ev):
        fired.append(("batch", eng.now))
        if ev.payload["n"] < 3:
            eng.schedule(1.0, EventKind.BATCH_DONE, n=ev.payload["n"] + 1)

    eng.register(EventKind.BATCH_DONE, on_batch)
    eng.schedule(1.0, EventKind.BATCH_DONE, n=0)
    eng.run()
    assert [t for _, t in fired] == [1.0, 2.0, 3.0, 4.0]


@pytest.mark.parametrize("scheduler", SCHEDULERS)
def test_negative_delay_and_past_rejected(scheduler):
    eng = SimEngine(scheduler)
    eng.register(EventKind.MOVE, lambda ev: None)
    eng.schedule(1.0, EventKind.MOVE)
    eng.run()
    with pytest.raises(ValueError):
        eng.schedule(-0.5, EventKind.MOVE)
    with pytest.raises(ValueError):
        eng.schedule_at(0.5, EventKind.MOVE)    # now is 1.0


def test_missing_handler_raises():
    eng = SimEngine()
    eng.schedule(0.0, EventKind.ROUND_BARRIER)
    with pytest.raises(KeyError):
        eng.run()


@pytest.mark.parametrize("scheduler", SCHEDULERS)
def test_until_and_max_events_bounds(scheduler):
    eng = SimEngine(scheduler)
    collect(eng)
    for i in range(5):
        eng.schedule(float(i), EventKind.BATCH_DONE)
    eng.run(until=2.5)
    assert eng.events_processed == 3 and eng.pending == 2
    eng.run(max_events=1)
    assert eng.events_processed == 4
    eng.run()
    assert eng.pending == 0


@pytest.mark.parametrize("scheduler", SCHEDULERS)
def test_stats_shape(scheduler):
    eng = SimEngine(scheduler)
    collect(eng)
    eng.schedule(1.0, EventKind.MOVE)
    eng.schedule(2.0, EventKind.MOVE)
    eng.schedule(1.5, EventKind.BATCH_DONE)
    eng.run()
    s = eng.stats()
    assert s["events_processed"] == 3
    assert s["by_kind"] == {"batch_done": 1, "move": 2}
    assert s["sim_time_s"] == 2.0
    assert s["events_per_sec"] > 0


@pytest.mark.parametrize("scheduler", SCHEDULERS)
def test_cancel_after_run_does_not_leak(scheduler):
    # regression: cancel() on an event that already ran used to park its
    # seq in _cancelled forever, permanently undercounting `pending`
    eng = SimEngine(scheduler)
    collect(eng)
    ev = eng.schedule(1.0, EventKind.MOVE)
    eng.run()
    assert eng.pending == 0
    eng.cancel(ev)                         # no-op: the event already ran
    assert eng.pending == 0 and not eng._cancelled
    live = eng.schedule(1.0, EventKind.MOVE)
    assert eng.pending == 1
    eng.run()
    assert eng.events_processed == 2       # the late cancel hid nothing


@pytest.mark.parametrize("scheduler", SCHEDULERS)
def test_cancel_is_idempotent_and_pending_exact(scheduler):
    eng = SimEngine(scheduler)
    collect(eng)
    evs = [eng.schedule(float(i), EventKind.MOVE) for i in range(4)]
    eng.cancel(evs[1])
    eng.cancel(evs[1])                     # double-cancel: one tombstone
    assert eng.pending == 3 and len(eng._cancelled) == 1
    eng.run()
    assert eng.events_processed == 3 and eng.pending == 0
    assert not eng._cancelled              # tombstone reclaimed at pop


@pytest.mark.parametrize("scheduler", SCHEDULERS)
def test_cancel_at_head_skips_without_advancing_clock(scheduler):
    eng = SimEngine(scheduler)
    seen = collect(eng)
    head = eng.schedule(1.0, EventKind.MOVE)
    eng.schedule(2.0, EventKind.BATCH_DONE)
    eng.cancel(head)
    assert eng.peek_time() == 2.0          # cancelled head never surfaces
    eng.run()
    assert [e.time for e in seen] == [2.0] and eng.now == 2.0


def test_unknown_scheduler_rejected():
    with pytest.raises(ValueError):
        SimEngine("wheel-of-fortune")


def test_calendar_schedule_below_cursor_after_cancelled_head():
    # a cancelled far-future head advances the calendar pop cursor when
    # it is reclaimed; a later schedule at the (earlier) engine clock
    # must still pop first — the push pulls the cursor back
    eng = SimEngine("calendar")
    seen = collect(eng)
    eng.schedule(1.0, EventKind.MOVE)
    eng.run()                              # now = 1.0
    far = eng.schedule_at(500.0, EventKind.MOVE)
    eng.cancel(far)
    assert eng.peek_time() is None         # reclaims the cancelled head
    eng.schedule_at(2.0, EventKind.BATCH_DONE)
    eng.schedule_at(7.5, EventKind.TRANSFER_DONE)
    eng.run()
    assert [e.time for e in seen] == [1.0, 2.0, 7.5]


def test_calendar_queue_matches_heapq_under_resize_churn():
    # direct queue-level differential, sized to cross grow + shrink
    # thresholds several times
    rng = random.Random(7)
    q, ref = CalendarQueue(), []
    last, seq = 0.0, 0
    for _ in range(20000):
        if ref and rng.random() < 0.45:
            want, got = heapq.heappop(ref), q.pop()
            assert want == got
            last = want[0]
        else:
            t = last + rng.random() * rng.choice([0.0, 0.01, 1.0, 500.0])
            entry = (t, rng.choice(["", "k1", "k2"]), seq)
            seq += 1
            heapq.heappush(ref, entry)
            q.push(entry)
    while ref:
        assert heapq.heappop(ref) == q.pop()
    assert len(q) == 0 and q.peek() is None


def test_make_queue_names():
    assert type(make_queue("calendar")) is CalendarQueue
    assert len(make_queue("heap")) == 0
    with pytest.raises(ValueError):
        make_queue("fifo")
