"""gemma2-9b — local+global alternating attention, logit softcaps
[arXiv:2408.00118]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14_336,
    vocab_size=256_000,
    sliding_window=4096,
    local_global_period=2,   # alternate local / global
    attn_softcap=50.0,
    logit_softcap=30.0,
    tie_embeddings=True,
    source="arXiv:2408.00118",
)
