"""Mobility traces: when which device moves between which edge servers.

The paper's experiments move one device (i) after 50% / 90% of training
(§V-B, Fig. 3) and (ii) at every 10th round of 100 (Fig. 4). We model a
trace as a list of ``MoveEvent``s; generators cover the paper's patterns
plus a Poisson arrival process for the "frequency of device mobility"
factor (§III).

``fraction`` ∈ [0, 1) is the position *inside the round's local epoch* at
which the device disconnects (the paper's "after 50%/90% of the training
is completed" maps to fraction=0.5/0.9 of the device's batches).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np


@dataclass(frozen=True)
class MoveEvent:
    round_idx: int          # FL round during which the move happens
    client_id: str
    src_edge: str
    dst_edge: str
    fraction: float = 0.0   # progress through the round's batches at move


def move_at_round(client_id: str, src: str, dst: str, round_idx: int,
                  fraction: float = 0.0) -> List[MoveEvent]:
    return [MoveEvent(round_idx, client_id, src, dst, fraction)]


def move_at_fraction(client_id: str, src: str, dst: str, total_rounds: int,
                     training_fraction: float,
                     round_fraction: float = 0.0) -> List[MoveEvent]:
    """Paper Fig. 3: move after ``training_fraction`` (0.5 / 0.9) of the
    full training run."""
    r = min(int(round(training_fraction * total_rounds)), total_rounds - 1)
    return [MoveEvent(r, client_id, src, dst, round_fraction)]


def periodic_moves(client_id: str, edges: Sequence[str], total_rounds: int,
                   period: int, fraction: float = 0.0) -> List[MoveEvent]:
    """Paper Fig. 4: move every ``period`` rounds, ping-ponging between
    edge servers."""
    events, cur = [], 0
    for r in range(period, total_rounds, period):
        nxt = (cur + 1) % len(edges)
        events.append(MoveEvent(r, client_id, edges[cur], edges[nxt],
                                fraction))
        cur = nxt
    return events


def poisson_moves(client_ids: Sequence[str], edges: Sequence[str],
                  total_rounds: int, rate_per_round: float,
                  seed: int = 0) -> List[MoveEvent]:
    """Random mobility: each round each client moves with prob
    1-exp(-rate); destination is a uniform different edge."""
    rng = np.random.default_rng(seed)
    location = {c: edges[i % len(edges)] for i, c in enumerate(client_ids)}
    events: List[MoveEvent] = []
    p = 1.0 - np.exp(-rate_per_round)
    for r in range(total_rounds):
        for c in client_ids:
            if rng.random() < p:
                others = [e for e in edges if e != location[c]]
                dst = others[rng.integers(len(others))]
                events.append(MoveEvent(r, c, location[c], dst,
                                        float(rng.random())))
                location[c] = dst
    return events


class MobilityTrace:
    """Indexable trace; the scheduler polls it once per (round, client)."""

    def __init__(self, events: Sequence[MoveEvent]):
        self._by_round = {}
        for e in events:
            self._by_round.setdefault(e.round_idx, []).append(e)
        self.events = list(events)

    def moves_in_round(self, round_idx: int) -> List[MoveEvent]:
        return list(self._by_round.get(round_idx, []))

    def move_for(self, round_idx: int, client_id: str) -> Optional[MoveEvent]:
        for e in self._by_round.get(round_idx, []):
            if e.client_id == client_id:
                return e
        return None
