"""The one table that scopes every rule to the files whose contract it
enforces. Paths are repo-root-relative with posix separators; a
directory entry covers everything under it. Tests override single keys
to point the rules at fixture trees (``run_analysis(root, config=...)``
merges onto this table), so nothing here is hard-wired into the rules
themselves.

Contract sources: docs/ARCHITECTURE.md §1–3 (wire protocol, JAX-free
shard state, pickle-free messages) and docs/OBSERVABILITY.md (wall
clocks only, instrumented-name table). docs/ANALYSIS.md documents each
rule against the contract it encodes.
"""
from __future__ import annotations

import copy
from typing import Any, Dict

DEFAULT_CONFIG: Dict[str, Any] = {
    # where Python modules live; module names derive from this root
    "src_root": "src",

    # -- jax-import-hygiene ------------------------------------------------
    # Modules documented as importable without JAX (ARCHITECTURE §2:
    # "JAX-free EdgeShard engines", §3.4 bootstrap: "a group only ...
    # pays the JAX import when its first train directive arrives"; the
    # telemetry plane is dependency-free by OBSERVABILITY invariant 3).
    # A trailing ".*" covers every submodule of a package.
    "jax_free_modules": [
        "repro.sim.shard",
        "repro.sim.soa",
        "repro.sim.sampling",
        "repro.sim.engine",
        "repro.sim.mailbox",
        "repro.sim.trainer",
        "repro.sim.agg_tree",
        "repro.runtime.transport",
        "repro.runtime.serialization",
        "repro.obs",
        "repro.obs.*",
    ],
    # import prefixes that count as "the JAX toolchain"
    "jax_modules": ["jax", "jaxlib", "flax", "optax"],

    # -- no-pickle-on-wire -------------------------------------------------
    # pickle is banned in this whole scope; the only exceptions are the
    # spawn-bootstrap sites carrying explicit allow markers (the wire
    # protocol itself is pickle-free — ARCHITECTURE §3.3).
    "pickle_scope": ["src/repro"],

    # -- clock-discipline --------------------------------------------------
    # within this scope, wall clocks (time.time / datetime.now) may be
    # read only by the telemetry snapshot's paired (mono_ns, wall_ns)
    # sample (ARCHITECTURE §3.6 rule 3); benchmarks/examples timing
    # user-visible elapsed wall time sit outside the contract
    "wall_clock_scope": ["src/repro"],
    "wall_clock_allowed": ["src/repro/obs/telemetry.py"],
    # numerics / replay-side modules where NO process clock of any kind
    # may be read: timing must come from simulated time alone, or
    # bit-identity across shard/worker/host counts dies.
    # (soa.py mirrors engine.py: its only clock is the perf_counter
    # wall-time *accounting* around run_window, never simulation state)
    "pure_sim_modules": [
        "src/repro/sim/shard.py",
        "src/repro/sim/sampling.py",
        "src/repro/sim/fleet.py",
        "src/repro/sim/async_agg.py",
        "src/repro/sim/agg_tree.py",
        "src/repro/core/fedavg.py",
        "src/repro/kernels",
    ],

    # -- deterministic-iteration -------------------------------------------
    # modules whose iteration order feeds the ordered replay or the
    # aggregation pipeline (ARCHITECTURE §2 "Numerics replay")
    "ordered_replay_modules": [
        "src/repro/sim/simulator.py",
        "src/repro/sim/fleet.py",
        "src/repro/sim/async_agg.py",
        "src/repro/sim/agg_tree.py",
    ],
    # stdlib random is banned everywhere under these scopes (seeded
    # np.random.Generator / jax.random only)
    "random_scope": ["src/repro"],

    # -- wire-spec-drift ---------------------------------------------------
    "architecture_doc": "docs/ARCHITECTURE.md",
    "observability_doc": "docs/OBSERVABILITY.md",
    # where the wire-tag codec lives (the closed "__w" tag set)
    "wire_tag_files": ["src/repro/sim/mailbox.py"],
    # files allowed to construct protocol messages ({"type": ...})
    "wire_message_files": [
        "src/repro/sim/mailbox.py",
        "src/repro/sim/trainer.py",
        "src/repro/sim/simulator.py",
    ],
    "serialization_file": "src/repro/runtime/serialization.py",
    # instrumentation scope for the OBSERVABILITY name table
    "obs_scope": ["src/repro"],

    # -- deadline-discipline -----------------------------------------------
    # the transport/recovery stack (ARCHITECTURE §3.7): every blocking
    # recv/get/join/wait/acquire here must carry timeout= or a reasoned
    # allow marker — the failover path cannot be built on unbounded waits
    "deadline_modules": [
        "src/repro/sim/mailbox.py",
        "src/repro/sim/trainer.py",
        "src/repro/runtime/transport.py",
    ],

    # -- lock-discipline ---------------------------------------------------
    # threaded modules whose with-nesting defines the lock order
    "lock_modules": [
        "src/repro/runtime/transport.py",
        "src/repro/sim/mailbox.py",
        "src/repro/sim/trainer.py",
        "src/repro/obs/telemetry.py",
    ],

    # -- doc-links ---------------------------------------------------------
    "doc_link_root": ".",
}


def make_config(overrides: Dict[str, Any] = None) -> Dict[str, Any]:
    cfg = copy.deepcopy(DEFAULT_CONFIG)
    if overrides:
        cfg.update(copy.deepcopy(overrides))
    return cfg
