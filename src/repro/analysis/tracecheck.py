"""Trace-schema validator: a merged telemetry trace must be valid
Chrome trace-event JSON (docs/OBSERVABILITY.md §Trace schema).

Engine behind ``scripts/check_trace.py`` (now a shim) and the
``python -m repro.analysis --trace`` mode. Checks:

  - top level is ``{"traceEvents": [...]}`` (object form)
  - every event is an object with a ``ph`` phase field
  - "X" complete events carry name/ts/dur/pid/tid, dur >= 0, ts is a
    number (Perfetto rejects events missing any of these)
  - "M" metadata events carry a known name (process_name / thread_name)
    and an ``args`` object
  - "C" counter events carry name/ts/pid and numeric ``args`` values
  - with ``require_ranks=N``: the trace contains X spans from at least
    N distinct pid lanes (each simulation rank maps to one pid — a
    multi-host run missing a rank's spans fails here)
  - with ``require_spans=[NAME, ...]``: each name appears on at least
    one X event
"""
from __future__ import annotations

import argparse
import json
import sys

_META_NAMES = {"process_name", "thread_name", "process_sort_index",
               "thread_sort_index"}


def _num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def check_trace(doc, require_ranks: int = 0,
                require_spans=()) -> list:
    """Return a list of violation strings (empty = valid)."""
    errs = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["top level must be an object with a 'traceEvents' key"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be a list"]

    span_pids = set()
    span_names = set()
    n_x = n_m = n_c = 0
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errs.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph == "X":
            n_x += 1
            for k in ("name", "ts", "dur", "pid", "tid"):
                if k not in ev:
                    errs.append(f"{where}: X event missing {k!r}")
            if not _num(ev.get("ts", 0)):
                errs.append(f"{where}: ts must be a number")
            if _num(ev.get("dur", 0)) and ev.get("dur", 0) < 0:
                errs.append(f"{where}: negative dur {ev['dur']}")
            if "pid" in ev:
                span_pids.add(ev["pid"])
            if "name" in ev:
                span_names.add(ev["name"])
        elif ph == "M":
            n_m += 1
            if ev.get("name") not in _META_NAMES:
                errs.append(f"{where}: unknown metadata name "
                            f"{ev.get('name')!r}")
            if not isinstance(ev.get("args"), dict):
                errs.append(f"{where}: M event needs an 'args' object")
        elif ph == "C":
            n_c += 1
            for k in ("name", "ts", "pid"):
                if k not in ev:
                    errs.append(f"{where}: C event missing {k!r}")
            args = ev.get("args")
            if not isinstance(args, dict) or not all(
                    _num(v) for v in args.values()):
                errs.append(f"{where}: C event args must be numeric")
        else:
            errs.append(f"{where}: unknown phase {ph!r}")

    if n_x == 0:
        errs.append("trace contains no X (span) events")
    if require_ranks and len(span_pids) < require_ranks:
        errs.append(f"spans cover {len(span_pids)} pid lanes "
                    f"({sorted(span_pids)}), need >= {require_ranks}")
    for name in require_spans:
        if name not in span_names:
            errs.append(f"required span {name!r} absent "
                        f"(have {sorted(span_names)})")
    if not errs:
        print(f"ok: {n_x} spans / {n_m} metadata / {n_c} counters, "
              f"pid lanes {sorted(span_pids)}")
    return errs


def check_trace_file(path: str, require_ranks: int = 0,
                     require_spans=()) -> list:
    """Load ``path`` and validate; unreadable/bad JSON is itself a
    violation."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable: {e}"]
    return check_trace(doc, require_ranks, require_spans)


def main(argv=None) -> int:
    """The ``scripts/check_trace.py`` entry point."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="path to the Chrome trace JSON")
    ap.add_argument("--require-ranks", type=int, default=0,
                    help="minimum distinct pid lanes with spans")
    ap.add_argument("--require-span", action="append", default=[],
                    metavar="NAME", help="span name that must appear")
    args = ap.parse_args(argv)

    errs = check_trace_file(args.trace, args.require_ranks,
                            args.require_span)
    for e in errs:
        print(f"{args.trace}: {e}", file=sys.stderr)
    return 1 if errs else 0
