"""CLI: ``python -m repro.analysis [--json] [--json-out FILE] [paths]``.

Exit status 0 = clean, 1 = findings (including parse errors and broken
suppression markers — an unparseable file or a typo'd marker must fail
the build, not silently disable nothing).

``--trace FILE`` switches to the trace-schema validator (same engine as
``scripts/check_trace.py``).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis import all_rules, run_analysis
from repro.analysis import tracecheck


def _find_root(start: Path) -> Path:
    """Nearest ancestor containing the source root (so the tool runs
    from anywhere inside the repo)."""
    start = start.resolve()
    for cand in [start, *start.parents]:
        if (cand / "src" / "repro").is_dir():
            return cand
    return start


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific static analysis "
                    "(docs/ANALYSIS.md has the rule catalogue)")
    ap.add_argument("paths", nargs="*",
                    help="extra files/dirs to lint beyond the source "
                         "root (e.g. scripts/ tests/)")
    ap.add_argument("--root", default=None,
                    help="repo root (default: nearest ancestor of cwd "
                         "containing src/repro)")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON on stdout")
    ap.add_argument("--json-out", metavar="FILE", default=None,
                    help="also write the JSON findings document to FILE")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    ap.add_argument("--trace", metavar="FILE", default=None,
                    help="validate a Chrome trace JSON instead of "
                         "linting source")
    ap.add_argument("--require-ranks", type=int, default=0,
                    help="(with --trace) minimum distinct pid lanes")
    ap.add_argument("--require-span", action="append", default=[],
                    metavar="NAME",
                    help="(with --trace) span name that must appear")
    args = ap.parse_args(argv)

    if args.trace is not None:
        errs = tracecheck.check_trace_file(
            args.trace, args.require_ranks, args.require_span)
        for e in errs:
            print(f"{args.trace}: {e}", file=sys.stderr)
        return 1 if errs else 0

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.name}: {rule.contract}")
        return 0

    root = Path(args.root) if args.root else _find_root(Path.cwd())
    findings = run_analysis(root, paths=[Path(p) for p in args.paths])

    doc = {"root": str(root), "count": len(findings),
           "findings": [f.as_json() for f in findings]}
    if args.json_out:
        Path(args.json_out).write_text(json.dumps(doc, indent=2) + "\n",
                                       encoding="utf-8")
    if args.json:
        print(json.dumps(doc, indent=2))
    else:
        for f in findings:
            print(f.format())
        if findings:
            print(f"{len(findings)} finding(s)")
        else:
            print("repro-lint OK "
                  f"({len(all_rules())} rules, no findings)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
