"""Serve a (reduced) assigned architecture with batched prefill+decode,
demonstrating the inference path the decode dry-run shapes lower.

  PYTHONPATH=src python examples/serve_batched.py --arch hymba-1.5b
"""
import argparse
import subprocess
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3-0.6b")
ap.add_argument("--gen", type=int, default=12)
args = ap.parse_args()

# the serve driver is the public entry point; this example just shows
# the invocation (and keeps a single source of truth for serving logic)
sys.exit(subprocess.call(
    [sys.executable, "-m", "repro.launch.serve", "--arch", args.arch,
     "--batch", "4", "--prompt-len", "32", "--gen", str(args.gen)]))
