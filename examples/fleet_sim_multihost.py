"""Multi-host fleet simulation: shard the FedFly event queue across
separate machines, connected only by TCP sockets.

Every host runs THIS binary with the same fleet arguments (the fleet is
rebuilt deterministically from the seed on each host — no state ships at
startup) and a rank picked from the shared address directory. Rank 0 is
the coordinator: it replays the cohort numerics from the record frames
the hosts stream back and prints the result; every rank — 0 included —
runs one shard-group host loop. The conservative-window barrier rides
the all-to-all mail exchange (``repro.sim.mailbox.SocketMailbox``), and
per-round metrics are bit-identical to a single-process run for any
host count (wire protocol: docs/ARCHITECTURE.md).

Two machines:

  # machine A (rank 0, coordinator)
  PYTHONPATH=src python examples/fleet_sim_multihost.py \
      --hosts 2 --rank 0 --listen 0.0.0.0:7070 \
      --connect hostA:7070,hostB:7071

  # machine B (rank 1)
  PYTHONPATH=src python examples/fleet_sim_multihost.py \
      --hosts 2 --rank 1 --listen 0.0.0.0:7071 \
      --connect hostA:7070,hostB:7071

Single machine (spawns the host processes itself, same socket protocol):

  PYTHONPATH=src python examples/fleet_sim_multihost.py --hosts 2
"""
import argparse
import json
import time

from repro.core.mobility import MobilityTrace, poisson_moves
from repro.models.vgg import VGG5
from repro.optim.optimizers import sgd
from repro.optim.schedules import constant
from repro.sim import (Fleet, FleetSimulator, hinge_staleness, make_edges,
                       make_fleet_specs)


def build_sim(args) -> FleetSimulator:
    """Deterministic from the arguments: every rank builds the identical
    simulator, so only sockets — never state — connect the hosts. Every
    rank also owns the cohort trainers for the cohorts its shards host;
    the coordinator ships global-model broadcasts and train directives
    over the control channel and gets update snapshots back."""
    edges = make_edges(args.edges, slots=64)
    specs = make_fleet_specs(args.devices, [e.edge_id for e in edges],
                             batch_size=16, num_batches=2,
                             cohorts=args.cohorts)
    fleet = Fleet(VGG5(), sgd(momentum=0.9), specs, split_point=2,
                  lr_schedule=constant(0.01),
                  max_replicas=args.max_replicas, seed=args.seed)
    trace = MobilityTrace(poisson_moves(
        [s.client_id for s in specs], [e.edge_id for e in edges],
        total_rounds=args.rounds, rate_per_round=0.05, seed=args.seed))
    async_kw = (dict(alpha=0.6,
                     staleness_fn=hinge_staleness(a=4.0 / args.devices,
                                                  b=2.0 * args.devices))
                if args.mode == "async" else {})
    return FleetSimulator(
        fleet, edges, trace=trace, mode=args.mode, **async_kw,
        shards=max(args.shards, args.hosts), measure_pack=False,
        hosts=args.hosts if args.rank is None else None,
        # telemetry observes wall clocks only — results stay
        # bit-identical; rank 0 merges every rank's spans into the trace
        telemetry=args.trace is not None,
        trace_path=args.trace if args.rank in (None, 0) else None)


def report(result, args, wall: float) -> None:
    es = result.engine_stats
    print(f"simulated {args.devices} devices x {args.rounds} rounds on "
          f"{args.edges} edges / {es['num_shards']} shards / "
          f"{es.get('num_hosts', 1)} hosts in {wall:.1f}s wall "
          f"({es['events_processed']} events, "
          f"{es['events_per_sec']:.0f} ev/s, "
          f"{es.get('windows', 1)} windows)")
    for r in result.rounds:
        print(f"  round {r['round_idx']}: {r['n_updates']} updates, "
              f"loss {r.get('mean_loss', float('nan')):.3f}, "
              f"round time {r.get('mean_round_time_s', 0.0):.2f}s sim")
    print(json.dumps(result.summary()))


def parse_addr(s: str):
    host, port = s.rsplit(":", 1)
    return host, int(port)


def main():
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--hosts", type=int, default=2,
                    help="number of shard-group host processes")
    ap.add_argument("--rank", type=int, default=None,
                    help="this machine's rank (omit to spawn every host "
                         "locally)")
    ap.add_argument("--listen", default=None, metavar="HOST:PORT",
                    help="address this rank binds (distributed mode)")
    ap.add_argument("--connect", default=None,
                    metavar="H0:P0,H1:P1,...",
                    help="comma-separated address of every rank, in rank "
                         "order (distributed mode)")
    ap.add_argument("--mode", choices=("sync", "async"), default="async",
                    help="sync uses the control-mail round restart — "
                         "multi-host sync barriers ride the same mesh")
    ap.add_argument("--devices", type=int, default=1000)
    ap.add_argument("--cohorts", type=int, default=1,
                    help="cohort signatures (>1 parallelizes the XLA "
                         "training across hosts)")
    ap.add_argument("--edges", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--max-replicas", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable telemetry; rank 0 writes the merged "
                         "Chrome/Perfetto trace here "
                         "(docs/OBSERVABILITY.md)")
    args = ap.parse_args()

    t0 = time.time()
    sim = build_sim(args)
    if args.rank is None:
        # localhost harness: FleetSimulator spawns the host processes,
        # still connected only by sockets
        result = sim.run(args.rounds)
        report(result, args, time.time() - t0)
        return
    if args.listen is None or args.connect is None:
        ap.error("distributed mode (--rank) needs --listen and --connect")
    addresses = {r: parse_addr(a)
                 for r, a in enumerate(args.connect.split(","))}
    if len(addresses) != args.hosts:
        ap.error(f"--connect lists {len(addresses)} addresses for "
                 f"--hosts {args.hosts}")
    result = sim.run_multihost(args.rounds, rank=args.rank,
                               listen=parse_addr(args.listen),
                               addresses=addresses)
    if result is not None:                        # rank 0
        report(result, args, time.time() - t0)
    else:
        print(f"rank {args.rank}: shard group complete in "
              f"{time.time() - t0:.1f}s wall")


if __name__ == "__main__":        # spawn-safe: hosts re-import this file
    main()
