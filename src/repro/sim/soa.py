"""Struct-of-arrays shard engine: the million-device hot path.

``SoAEdgeShard`` is a drop-in replacement for ``repro.sim.shard.
EdgeShard`` (same constructor, same window protocol, same records on
the wire) that stores per-client timing state in dense slot-indexed
columns instead of one ``ShardClient`` object per device, and pops
events through a lean tuple loop instead of ``SimEngine``'s
Event-object dispatch. ``ShardClient`` objects exist only at the wire
boundary: a cross-shard migration materializes one into the Mail
payload (so the mailbox codec and recovery replay are untouched) and
an arriving one is scattered back into the columns.

Three structural differences from the object engine, none observable:

  * **Hybrid columns.** Numpy columns hold what the vectorized paths
    read per *population* — per-client pricing on the current edge
    (``downlink``/``fixed``/``srv``, from the same float expressions
    ``shard.batch_parts`` evaluates), edge index, done flag, sampling
    digests, and the in-flight batch progress (which congestion
    re-pricing rewrites in bulk). Plain Python lists hold the scalars
    the per-event path touches (ids, batch counters, epoch clocks): a
    numpy scalar index costs ~5-10x a list index (boxing), and the
    event loop reads a handful of scalars per event, so lists win
    there.
  * **Per-edge batch heaps.** In-flight batches live in a small
    ``heapq`` per edge, keyed ``(finish, client_id, slot)``; the
    global queue carries only each edge's *head* batch. Congestion
    re-pricing — which rewrites every in-flight finish time on an edge
    at once — becomes one vectorized recompute plus one O(n) heapify,
    instead of n cancel+reschedule round-trips through the global
    queue. The object engine pushes ~2 cancelled entries through its
    heap for every delivered event; this layout removes that churn
    entirely, which is where most of the headline speedup comes from.
  * **Lean events.** Global-queue entries are bare tuples ``(time,
    key, seq, kind_int, arg)`` — no Event allocation, no payload
    dicts. The queue itself is pluggable (``scheduler="heap" |
    "calendar"``, shared classes from ``repro.sim.engine``).

Bit-identity contract (proven by tests/test_soa_shard.py): for any
scenario both engines can run, the records a window hands back —
contribs, epoch_starts, migrations — and the mail it emits are
*identical Python values* to the object path's. That holds because

  * every float is produced by the same IEEE operation sequence: the
    per-client pricing terms are precomputed with exactly the scalar
    expressions the object path evaluates per batch (floats are
    deterministic, so compute-once equals compute-every-time), and the
    vectorized wave/re-price paths group their arithmetic exactly like
    the scalar path (``finish = (start + fixed) + srv*g``) — numpy
    float64 elementwise ops are bit-identical to the equivalent Python
    float ops, and ``np.where``/``np.maximum`` select, not perturb;
  * iteration and scheduling order follow the *client-id string*
    order the object path uses (ids above 10k devices are not
    zero-padded to equal width, so string order != numeric order —
    slots are therefore ordered through an explicit sorted-id index,
    never through their numeric value);
  * global entries carry the same ``(time, key, seq)`` tie-break with
    the client id as the key, and the per-edge heaps order by
    ``(finish, client_id)`` — the same total order the object engine's
    flat queue yields, because two live batches never share a client
    and a client never has two events queued at the same instant;
  * delivered-event counts match: an edge-head entry pops exactly when
    the object engine would deliver that batch's BATCH_DONE, and
    superseded head entries die by seq tombstone without being
    counted, exactly like ``SimEngine.cancel``.

JAX-free and clock-disciplined like ``shard.py``: wall clocks are only
measured for throughput stats, never used to order events.
"""
from __future__ import annotations

import time
from heapq import heapify, heappop, heappush
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.sim import sampling as _sampling
from repro.sim.engine import EventKind, Mail, WindowResult, make_queue
from repro.sim.shard import CohortTable, ShardClient, ShardEdge

# lean event kinds (ints, not EventKind — dispatch is an if/elif chain
# on small ints). Update and migration transfers are distinct kinds so
# entries need no payload; both report as "transfer_done".
K_BATCH = 0
K_MOVE = 1
K_PACKED = 2
K_XFER_UPDATE = 3
K_XFER_MIG = 4
K_REJOIN = 5
K_RSTART = 6

_KIND_NAME = {
    K_BATCH: EventKind.BATCH_DONE.value,
    K_MOVE: EventKind.MOVE.value,
    K_PACKED: EventKind.CHECKPOINT_PACKED.value,
    K_XFER_UPDATE: EventKind.TRANSFER_DONE.value,
    K_XFER_MIG: EventKind.TRANSFER_DONE.value,
    K_REJOIN: EventKind.REJOIN.value,
    K_RSTART: EventKind.ROUND_START.value,
}

# below this many in-flight batches, scalar re-pricing beats the numpy
# fixed overhead (array alloc + dispatch ~ a dozen microseconds)
_VEC_REPRICE_MIN = 16


class SoAEdgeShard:
    """One shard of the fleet, columnar: its edges, its client columns,
    its per-edge batch heaps, its lean event loop."""

    def __init__(self, shard_id: int, edges: List[ShardEdge],
                 clients: List[ShardClient],
                 cohort_tables: Dict[Tuple[int, int], CohortTable],
                 shard_of_edge: Dict[str, int], *,
                 mode: str, num_rounds: int,
                 pack_fn: Optional[Any] = None,
                 reprice_tol: float = 0.05,
                 sampling: Optional[Tuple[int, float]] = None,
                 scheduler: str = "heap"):
        if pack_fn is not None:
            raise ValueError("SoAEdgeShard prices migrations from the "
                             "cached cohort tables (measure_pack=False)")
        self.shard_id = shard_id
        self.edges = {e.edge_id: e for e in edges}
        self.tables = cohort_tables
        self.shard_of_edge = shard_of_edge
        self.mode = mode
        self.num_rounds = num_rounds
        self.reprice_tol = reprice_tol
        self.sampling = sampling

        # -- static per-(edge, cohort) pricing scalars, precomputed with
        # the exact Python-float expressions the object path evaluates
        # per client (shard.batch_parts / _downlink_time)
        self._edge_ids = sorted(self.edges)
        self._eidx = {eid: i for i, eid in enumerate(self._edge_ids)}
        self._edge_list = [self.edges[eid] for eid in self._edge_ids]
        self._ckeys = sorted(cohort_tables)
        self._cidx = {k: i for i, k in enumerate(self._ckeys)}
        ne, nc = len(self._edge_ids), len(self._ckeys)
        self._tab_fixed_a = [[0.0] * nc for _ in range(ne)]  # 3*dflops
        self._tab_fixed_b = [[0.0] * nc for _ in range(ne)]  # 2*wtt
        self._tab_srv = [[0.0] * nc for _ in range(ne)]      # 3*sflops/F
        self._tab_downlink = [[0.0] * nc for _ in range(ne)]
        self._upload_bytes = [0] * nc
        self._ckpt_bytes = [0] * nc
        for ei, eid in enumerate(self._edge_ids):
            e = self.edges[eid]
            for ci, ck in enumerate(self._ckeys):
                t = cohort_tables[ck]
                self._tab_fixed_a[ei][ci] = 3.0 * t["dflops"]
                self._tab_fixed_b[ei][ci] = \
                    2.0 * e.wireless.transfer_time(int(t["sbytes"]))
                self._tab_srv[ei][ci] = 3.0 * t["sflops"] / e.flops_per_s
                self._tab_downlink[ei][ci] = \
                    e.wireless.transfer_time(int(t["dev"]))
        for ci, ck in enumerate(self._ckeys):
            self._upload_bytes[ci] = int(cohort_tables[ck]["update"])
            self._ckpt_bytes[ci] = int(cohort_tables[ck]["ckpt"])

        # -- client columns (slot-indexed, append-only; slots are
        # ordered through _order, never through their numeric value)
        self._ids: List[str] = []
        self._slot_of_id: Dict[str, int] = {}
        self._present: List[bool] = []
        self._done: List[bool] = []
        self._cohort: List[int] = []
        self._replica: List[int] = []
        self._edge: List[int] = []
        self._num_samples: List[int] = []
        self._nb: List[int] = []
        self._dev_flops: List[float] = []
        # per-client pricing on the CURRENT edge (re-derived when a
        # migration re-homes the client): batch_parts / downlink values
        self._fixed: List[float] = []
        self._srv: List[float] = []
        self._downlink: List[float] = []
        self._epoch: List[int] = []
        self._batch_idx: List[int] = []
        self._epochs_done: List[int] = []
        self._epoch_start_s: List[float] = []
        self._pulled_s: List[float] = []
        self._move_at: List[int] = []
        # in-flight batch progress (InflightBatch as four numpy columns
        # — re-pricing reads and rewrites them in bulk; fixed_s/srv_s
        # are the static _fixed/_srv of the client)
        self._fbr = np.zeros(0)       # remaining base-seconds
        self._fbl = np.zeros(0)       # last re-pricing time
        self._fbc = np.zeros(0)       # congestion in force since
        self._fbf = np.zeros(0)       # scheduled finish time
        # numpy mirrors for the vectorized wave + sampling
        self._edge_np = np.zeros(0, dtype=np.int64)
        self._done_np = np.zeros(0, dtype=bool)
        self._fixed_np = np.zeros(0)
        self._srv_np = np.zeros(0)
        self._downlink_np = np.zeros(0)
        self._digests: Optional[np.ndarray] = None   # uint64, lazy
        # sparse per-client state (dicts keyed by slot)
        self._moves: Dict[int, Dict[int, Tuple[str, float]]] = {}
        self._dropout: Dict[int, Tuple[int, float]] = {}
        self._pending_move: Dict[int, Tuple[str, float]] = {}
        self._inflight_mig: Dict[int, Dict[str, Any]] = {}
        for c in sorted(clients, key=lambda c: c.client_id):
            self._install(c)
        self._sync_mirrors()
        self._order = np.array(
            [self._slot_of_id[cid] for cid in sorted(self._slot_of_id)],
            dtype=np.int64)
        self._order_dirty = False

        # -- per-edge in-flight batches: membership set, (finish, id,
        # slot) heap, and a merged heap of every edge's *head* batch
        # (entries (finish, id, version, edge); an entry is live iff
        # the edge's head flag is set and its version matches — stale
        # entries are skipped at pop, the lazy-deletion idiom)
        self._einflight: List[set] = [set() for _ in self._edge_ids]
        self._eheap: List[list] = [[] for _ in self._edge_ids]
        self._bheads: list = []
        self._ehead_live: List[bool] = [False] * ne
        self._ehead_ver: List[int] = [0] * ne
        self._ehead_time: List[float] = [0.0] * ne
        self._ehead_key: List[str] = [""] * ne
        self._eden: List[int] = [max(e.slots, 1) for e in self._edge_list]

        # -- lean engine state
        self._queue = make_queue(scheduler)
        self._seq = 0
        self._tombstones: set = set()
        self._qmut = 0            # bumps on push: invalidates cached head
        self.now = 0.0
        self.events_processed = 0
        self._counts: Dict[int, int] = {k: 0 for k in _KIND_NAME}
        self.wall_s = 0.0
        self._epoch_reported: set = set()    # (cohort idx, epoch) pairs
        self._reset_outbox()

    # -- client slot management ------------------------------------------

    def _price_slot(self, s: int) -> None:
        """(Re-)derive the client's per-batch pricing for its current
        edge — the same scalar expressions ``shard.batch_parts`` and
        ``_downlink_time`` evaluate, computed once per (client, edge)
        instead of once per batch (floats are deterministic, so the
        values are bit-identical)."""
        ei, ci = self._edge[s], self._cohort[s]
        if ei < 0:            # mid-migration arrival: priced at re-home
            self._fixed[s] = self._srv[s] = self._downlink[s] = 0.0
            return
        self._fixed[s] = self._tab_fixed_a[ei][ci] / self._dev_flops[s] \
            + self._tab_fixed_b[ei][ci]
        self._srv[s] = self._tab_srv[ei][ci]
        self._downlink[s] = self._tab_downlink[ei][ci]

    def _install(self, c: ShardClient) -> int:
        """Scatter one ShardClient into the columns (build time and
        migration arrival — the only moments objects exist). A client
        arriving from another shard still names its *source* edge (the
        object path keeps edge_id until the migration resumes); its
        edge column holds -1 until ``_on_transfer_mig`` re-homes it,
        and nothing reads it before then."""
        s = self._slot_of_id.get(c.client_id)
        if s is None:
            s = len(self._ids)
            self._slot_of_id[c.client_id] = s
            self._ids.append(c.client_id)
            for col in (self._present, self._done):
                col.append(False)
            for col in (self._cohort, self._replica, self._edge,
                        self._num_samples, self._nb, self._epoch,
                        self._batch_idx, self._epochs_done,
                        self._move_at):
                col.append(0)
            for col in (self._dev_flops, self._fixed, self._srv,
                        self._downlink, self._epoch_start_s,
                        self._pulled_s):
                col.append(0.0)
            if s >= len(self._fbr):
                grow = max(64, len(self._fbr))
                z = np.zeros(grow)
                self._fbr = np.concatenate([self._fbr, z])
                self._fbl = np.concatenate([self._fbl, z])
                self._fbc = np.concatenate([self._fbc, z])
                self._fbf = np.concatenate([self._fbf, z])
        self._present[s] = True
        self._done[s] = c.done
        self._cohort[s] = self._cidx[c.cohort_key]
        self._replica[s] = c.replica
        self._edge[s] = self._eidx.get(c.edge_id, -1)
        self._num_samples[s] = c.num_samples
        self._nb[s] = c.num_batches
        self._dev_flops[s] = c.dev_flops_per_s
        self._epoch[s] = c.epoch
        self._batch_idx[s] = c.batch_idx
        self._epochs_done[s] = c.epochs_done
        self._epoch_start_s[s] = c.epoch_start_s
        self._pulled_s[s] = c.pulled_s
        self._move_at[s] = c.move_at
        self._price_slot(s)
        if c.moves:
            self._moves[s] = dict(c.moves)
        if c.dropout is not None:
            self._dropout[s] = c.dropout
        if c.pending_move is not None:
            self._pending_move[s] = c.pending_move
        return s

    def _sync_mirrors(self) -> None:
        """Rebuild the numpy mirrors of the slot columns (bulk install
        paths: construction, cross-shard arrivals)."""
        self._edge_np = np.array(self._edge, dtype=np.int64)
        self._done_np = np.array(self._done, dtype=bool)
        self._fixed_np = np.array(self._fixed)
        self._srv_np = np.array(self._srv)
        self._downlink_np = np.array(self._downlink)
        if self._digests is not None and \
                len(self._digests) < len(self._ids):
            tail = _sampling.digests_for(self._ids[len(self._digests):])
            self._digests = np.concatenate([self._digests, tail])

    def _materialize(self, s: int) -> ShardClient:
        """Rebuild the wire-format ShardClient for a departing slot."""
        return ShardClient(
            client_id=self._ids[s],
            cohort_key=self._ckeys[self._cohort[s]],
            replica=self._replica[s],
            edge_id=self._edge_ids[self._edge[s]],
            num_samples=self._num_samples[s],
            num_batches=self._nb[s],
            dev_flops_per_s=self._dev_flops[s],
            moves=self._moves.get(s, {}),
            dropout=self._dropout.get(s),
            epoch=self._epoch[s],
            batch_idx=self._batch_idx[s],
            epochs_done=self._epochs_done[s],
            epoch_start_s=self._epoch_start_s[s],
            pulled_s=self._pulled_s[s],
            pending_move=self._pending_move.get(s),
            move_at=self._move_at[s],
            batch_event=None,
            done=self._done[s])

    def _ordered_slots(self) -> np.ndarray:
        """Present slots in client-id *string* order (the object path's
        ``sorted(self.clients)``)."""
        if self._order_dirty:
            self._order = np.array(
                sorted((s for s in self._slot_of_id.values()
                        if self._present[s]),
                       key=self._ids.__getitem__), dtype=np.int64)
            self._order_dirty = False
        return self._order

    # -- lean event queue ------------------------------------------------

    def _push(self, t: float, key: str, kind: int, arg: int) -> int:
        if t < self.now:
            raise ValueError(f"cannot schedule kind {kind} in the past "
                             f"({t} < {self.now})")
        seq = self._seq
        self._seq += 1
        self._qmut += 1
        self._queue.push((t, key, seq, kind, arg))
        return seq

    def _head(self) -> Optional[tuple]:
        head = self._queue.peek()
        while head is not None and head[2] in self._tombstones:
            self._tombstones.discard(self._queue.pop()[2])
            head = self._queue.peek()
        return head

    # -- per-edge batch heaps --------------------------------------------

    def _refresh_head(self, ei: int) -> None:
        """Make the merged batch-head heap's entry for edge ``ei`` match
        its batch heap's minimum (superseding any stale entry by version
        bump). Idempotent — callers invoke it after any heap mutation."""
        h = self._eheap[ei]
        if not h:
            self._ehead_live[ei] = False
            return
        t, key, _s = h[0]
        if self._ehead_live[ei] and self._ehead_time[ei] == t \
                and self._ehead_key[ei] == key:
            return
        ver = self._ehead_ver[ei] + 1
        self._ehead_ver[ei] = ver
        self._ehead_live[ei] = True
        self._ehead_time[ei] = t
        self._ehead_key[ei] = key
        heappush(self._bheads, (t, key, ver, ei))

    def _rebuild_eheap(self, ei: int,
                       slots: Optional[np.ndarray] = None) -> None:
        """Re-key edge ``ei``'s batch heap from the (just re-priced)
        finish column. O(n) heapify instead of n cancel+reschedule
        round-trips through the global queue."""
        if slots is None:
            sl = list(self._einflight[ei])
            times = [float(self._fbf[s]) for s in sl]
        else:
            times = self._fbf[slots].tolist()
            sl = slots.tolist()
        # heap layout depends on input order, pop order does not (keys
        # are distinct tuples), so no need to sort the slot set first
        h = list(zip(times, map(self._ids.__getitem__, sl), sl))
        heapify(h)
        self._eheap[ei] = h
        self._refresh_head(ei)

    # -- window protocol -------------------------------------------------

    def _reset_outbox(self):
        self.out_mail: List[Mail] = []
        self.out_contribs: List[tuple] = []
        self.out_epoch_starts: List[tuple] = []
        self.out_migrations: List[tuple] = []

    def _batch_head(self) -> Optional[tuple]:
        """Live minimum of the merged batch-head heap (drains stale
        entries left behind by re-pricing / head churn)."""
        bh = self._bheads
        live = self._ehead_live
        ver = self._ehead_ver
        while bh:
            top = bh[0]
            if live[top[3]] and ver[top[3]] == top[2]:
                return top
            heappop(bh)
        return None

    def peek(self) -> Optional[float]:
        head = self._head()
        bh = self._batch_head()
        if head is None:
            return bh[0] if bh is not None else None
        if bh is None or (head[0], head[1]) < (bh[0], bh[1]):
            return head[0]
        return bh[0]

    def deliver(self, mail: List[Mail]) -> None:
        grew = False
        for m in sorted(mail, key=lambda m: (m.time, m.key)):
            if m.kind is EventKind.ROUND_START:
                self._push(m.time, m.key, K_RSTART,
                           m.payload["round_idx"])
                continue
            if m.kind is EventKind.TRANSFER_DONE and \
                    m.payload.get("what") == "migration":
                s = self._install(m.payload["client_state"])
                if s >= len(self._edge_np):
                    grew = True       # mirrors rebuilt once, below
                else:
                    self._edge_np[s] = self._edge[s]
                    self._done_np[s] = self._done[s]
                    self._fixed_np[s] = self._fixed[s]
                    self._srv_np[s] = self._srv[s]
                    self._downlink_np[s] = self._downlink[s]
                self._order_dirty = True
                self._inflight_mig[s] = m.payload["mig"]
                self._push(m.time, m.key, K_XFER_MIG, s)
                continue
            raise ValueError(f"unexpected cross-shard mail kind {m.kind}")
        if grew:
            self._sync_mirrors()

    def run_window(self, bound: float, mail: List[Mail]) -> WindowResult:
        wall0 = time.perf_counter()
        processed0 = self.events_processed
        self.deliver(mail)
        self._run(bound)
        result = WindowResult(
            next_time=self.peek(),
            mail=self.out_mail,
            records={"contribs": self.out_contribs,
                     "epoch_starts": self.out_epoch_starts,
                     "migrations": self.out_migrations},
            processed=self.events_processed - processed0)
        self._reset_outbox()
        self.wall_s += time.perf_counter() - wall0
        return result

    def final_stats(self) -> Dict[str, Any]:
        by_kind: Dict[str, int] = {}
        for k, n in self._counts.items():
            if n:
                name = _KIND_NAME[k]
                by_kind[name] = by_kind.get(name, 0) + n
        return {"engine": {
                    "events_processed": self.events_processed,
                    "events_per_sec": (self.events_processed / self.wall_s
                                       if self.wall_s > 0 else 0.0),
                    "sim_time_s": self.now,
                    "wall_s": self.wall_s,
                    "by_kind": dict(sorted(by_kind.items()))},
                "edges": [self.edges[eid].stats()
                          for eid in self._edge_ids]}

    # -- the loop --------------------------------------------------------

    def _run(self, before: float) -> None:
        counts = self._counts
        queue = self._queue
        tomb = self._tombstones
        eheap = self._eheap
        bheads = self._bheads
        live = self._ehead_live
        ver = self._ehead_ver
        on_batch_done = self._on_batch_done
        refresh = self._refresh_head
        n_events = 0
        n_batch = 0
        head = None
        hmut = -1
        while True:
            # the global head only changes on a pop (below) or a push
            # (any handler may schedule) — cache it across the hot batch
            # dispatches, which touch only the per-edge heaps
            if hmut != self._qmut:
                head = queue.peek()
                while head is not None and head[2] in tomb:
                    tomb.discard(queue.pop()[2])
                    head = queue.peek()
                hmut = self._qmut
            while bheads:
                top = bheads[0]
                if live[top[3]] and ver[top[3]] == top[2]:
                    break
                heappop(bheads)
            # merge the two queues on (time, key) — same total order as
            # the object engine's flat queue (ties across queues need a
            # shared key namespace; client ids vs coordinator keys)
            if bheads and (head is None or
                           (bheads[0][0], bheads[0][1]) <
                           (head[0], head[1])):
                t = bheads[0][0]
                if t >= before:
                    break
                _t, _key, _ver, ei = heappop(bheads)
                self.now = t
                t2, _key2, s = heappop(eheap[ei])
                assert t2 == t
                live[ei] = False
                on_batch_done(s, ei)
                if not live[ei]:
                    refresh(ei)
                n_events += 1
                n_batch += 1
                continue
            if head is None or head[0] >= before:
                break
            t, _key, _seq, kind, arg = queue.pop()
            hmut = -1
            self.now = t
            if kind == K_MOVE:
                self._on_move(arg)
            elif kind == K_PACKED:
                self._on_packed(arg)
            elif kind == K_XFER_UPDATE:
                self._on_transfer_update(arg)
            elif kind == K_XFER_MIG:
                self._on_transfer_mig(arg)
            elif kind == K_REJOIN:
                self._upload_update(arg)
            else:
                self._mass_start(arg, t)
            n_events += 1
            counts[kind] += 1
        self.events_processed += n_events
        counts[K_BATCH] += n_batch

    # -- congestion re-pricing (mirrors shard.py exactly) ----------------

    def _active_changed(self, ei: int) -> None:
        e = self._edge_list[ei]
        # inline congestion(): division kept for bit-identity
        g = e.active / self._eden[ei]
        if g < 1.0:
            g = 1.0
        ref = e.priced_cong
        if ref > 0 and abs(g - ref) <= self.reprice_tol * ref:
            return
        e.priced_cong = g
        inf = self._einflight[ei]
        if not inf:
            return
        now = self.now
        if len(inf) < _VEC_REPRICE_MIN:
            changed = False
            for s in sorted(inf):
                cold = float(self._fbc[s])
                if cold == g:
                    continue
                # InflightBatch.reprice, columnar: advance under the
                # old factor, switch to the new one
                fixed = self._fixed[s]
                srv = self._srv[s]
                last_t = float(self._fbl[s])
                remaining = float(self._fbr[s])
                if now > last_t:
                    rate_old = (fixed + srv) / (fixed + srv * cold)
                    remaining = max(
                        remaining - (now - last_t) * rate_old, 0.0)
                    self._fbr[s] = remaining
                    self._fbl[s] = last_t = now
                self._fbc[s] = g
                rate_new = (fixed + srv) / (fixed + srv * g)
                self._fbf[s] = last_t + remaining / rate_new
                changed = True
            if changed:
                self._rebuild_eheap(ei)
            return
        slots = np.fromiter(inf, dtype=np.int64, count=len(inf))
        cong = self._fbc[slots]
        chg = np.flatnonzero(cong != g)
        if not len(chg):
            return
        sl = slots[chg]
        fixed = self._fixed_np[sl]
        srv = self._srv_np[sl]
        tot = fixed + srv
        last = self._fbl[sl]
        rem = self._fbr[sl]
        adv = now > last
        if adv.any():
            rate_old = tot / (fixed + srv * cong[chg])
            rem = np.where(adv,
                           np.maximum(rem - (now - last) * rate_old, 0.0),
                           rem)
            last = np.where(adv, now, last)
            self._fbr[sl] = rem
            self._fbl[sl] = last
        self._fbc[sl] = g
        rate_new = tot / (fixed + srv * g)
        self._fbf[sl] = last + rem / rate_new
        self._rebuild_eheap(ei, slots)

    def _train_resume(self, ei: int) -> None:
        e = self._edge_list[ei]
        a = e.active + 1
        e.active = a
        if a > e.peak_active:
            e.peak_active = a
        self._active_changed(ei)

    def _train_pause(self, ei: int) -> None:
        e = self._edge_list[ei]
        a = e.active - 1
        e.active = a if a > 0 else 0
        self._active_changed(ei)

    def _begin_batch(self, s: int, start_s: float) -> None:
        ei = self._edge[s]
        fixed = self._fixed[s]
        srv = self._srv[s]
        # inline congestion(): division kept for bit-identity
        g = self._edge_list[ei].active / self._eden[ei]
        if g < 1.0:
            g = 1.0
        # same grouping as shard._begin_batch: start + fixed + srv*g
        finish = (start_s + fixed) + srv * g
        self._fbr[s] = fixed + srv
        self._fbl[s] = start_s
        self._fbc[s] = g
        self._fbf[s] = finish
        self._einflight[ei].add(s)
        cid = self._ids[s]
        heappush(self._eheap[ei], (finish, cid, s))
        # head only changes if the new batch undercuts the advertised one
        if not self._ehead_live[ei] or \
                (finish, cid) < (self._ehead_time[ei], self._ehead_key[ei]):
            self._refresh_head(ei)

    # -- epoch lifecycle -------------------------------------------------

    def _record_epoch_start(self, ci: int, epoch: int) -> None:
        key = (ci, epoch)
        if key not in self._epoch_reported:
            self._epoch_reported.add(key)
            self.out_epoch_starts.append(
                (self.now, self._ckeys[ci], epoch))

    def _setup_epoch(self, s: int, epoch: int, start_s: float) -> None:
        """Shared scalar tail of start_epoch: move bookkeeping + first
        batch (or immediate MOVE). Caller has set epoch/pulled/start
        columns and bumped the edge's active count."""
        ms = self._moves.get(s)
        move = ms.get(epoch) if ms else None
        if move is not None:
            nb = self._nb[s]
            self._pending_move[s] = move
            self._move_at[s] = min(int(round(move[1] * nb)), nb - 1)
        else:
            self._pending_move.pop(s, None)
            self._move_at[s] = -1
        if self._move_at[s] == 0:
            self._push(start_s, self._ids[s], K_MOVE, s)
        else:
            self._begin_batch(s, start_s)

    def _start_epoch(self, s: int, epoch: int, start_s: float) -> None:
        """Single-client epoch start (async next-epoch path): same
        sequence as shard.start_epoch with resume=True."""
        self._epoch[s] = epoch
        self._batch_idx[s] = 0
        self._epoch_start_s[s] = start_s
        self._pulled_s[s] = self.now
        self._record_epoch_start(self._cohort[s], epoch)
        self._train_resume(self._edge[s])
        self._setup_epoch(s, epoch, start_s)

    def _mass_start(self, epoch: int, base: float) -> None:
        """The vectorized round-start wave. Arithmetic is grouped
        exactly like the scalar path — ``start = base + downlink``,
        ``finish = (start + fixed) + srv*g`` — so every float matches
        the object engine bit for bit."""
        order = self._ordered_slots()
        wave = order[~self._done_np[order]]
        if self.sampling is not None and self.sampling[1] < 1.0 \
                and len(wave):
            if self._digests is None:
                self._digests = _sampling.digests_for(self._ids)
            seed, fraction = self.sampling
            mask = _sampling.participation_mask(
                self._digests[wave], seed, epoch, fraction)
            wave = wave[mask]
        if not len(wave):
            return
        ne = len(self._edge_list)
        ei = self._edge_np[wave]
        # count the whole wave into `active` first, re-price each edge
        # once, then schedule everyone at the settled congestion
        per_edge = np.bincount(ei, minlength=ne)
        touched = np.flatnonzero(per_edge)
        g_edge = np.zeros(ne)
        for e in touched:
            edge = self._edge_list[e]
            edge.active += int(per_edge[e])
            edge.peak_active = max(edge.peak_active, edge.active)
            self._active_changed(int(e))
            g_edge[e] = edge.congestion()
        start = base + self._downlink_np[wave]
        fixed = self._fixed_np[wave]
        srv = self._srv_np[wave]
        g = g_edge[ei]
        finish = (start + fixed) + srv * g
        self._fbr[wave] = fixed + srv
        self._fbl[wave] = start
        self._fbc[wave] = g
        self._fbf[wave] = finish
        now = self.now
        ids = self._ids
        moves = self._moves
        cohort = self._cohort
        edge_col = self._edge
        einflight = self._einflight
        eheap = self._eheap
        epoch_col = self._epoch
        batch_col = self._batch_idx
        es_col = self._epoch_start_s
        pulled_col = self._pulled_s
        move_col = self._move_at
        reported = self._epoch_reported
        push = self._push
        start_l = start.tolist()
        finish_l = finish.tolist()
        for i, s in enumerate(wave.tolist()):
            epoch_col[s] = epoch
            batch_col[s] = 0
            es_col[s] = start_l[i]
            pulled_col[s] = now
            ci = cohort[s]
            if (ci, epoch) not in reported:
                self._record_epoch_start(ci, epoch)
            if s in moves:
                # movers take the scalar path (sparse by construction)
                move = moves[s].get(epoch)
                if move is None:
                    self._pending_move.pop(s, None)
                    move_col[s] = -1
                else:
                    nb = self._nb[s]
                    self._pending_move[s] = move
                    move_col[s] = min(int(round(move[1] * nb)), nb - 1)
                    if move_col[s] == 0:
                        # no batch begins; the in-flight columns are
                        # rewritten when the migration lands
                        push(start_l[i], ids[s], K_MOVE, s)
                        continue
            else:
                move_col[s] = -1
            e = edge_col[s]
            einflight[e].add(s)
            heappush(eheap[e], (finish_l[i], ids[s], s))
        for e in touched:
            self._refresh_head(int(e))

    def bootstrap_async(self) -> None:
        self._mass_start(0, 0.0)

    def _on_batch_done(self, s: int, ei: int) -> None:
        self._einflight[ei].discard(s)
        b = self._batch_idx[s] + 1
        self._batch_idx[s] = b
        if b == self._move_at[s] and s in self._pending_move:
            self._push(self.now, self._ids[s], K_MOVE, s)
            return
        if b < self._nb[s]:
            self._begin_batch(s, self.now)
        else:
            self._epoch_computed(s)

    def _epoch_computed(self, s: int) -> None:
        self._train_pause(self._edge[s])
        drop = self._dropout.get(s)
        if drop is not None and drop[0] == self._epoch[s]:
            self._push(self.now + drop[1], self._ids[s], K_REJOIN, s)
            return
        self._upload_update(s)

    def _upload_update(self, s: int) -> None:
        nbytes = self._upload_bytes[self._cohort[s]]
        _, done, _ = self._edge_list[self._edge[s]].reserve_backhaul(
            self.now, nbytes)
        self._push(done, self._ids[s], K_XFER_UPDATE, s)

    # -- migration (FedFly steps 6-9, with backpressure) -----------------

    def _on_move(self, s: int) -> None:
        dst_edge, _ = self._pending_move.pop(s)
        ei = self._edge[s]
        src = self._edge_list[ei]
        self._train_pause(ei)
        src.attached = max(src.attached - 1, 0)
        src.migrations_out += 1
        nbytes = self._ckpt_bytes[self._cohort[s]]
        self._inflight_mig[s] = {
            "dst": dst_edge, "nbytes": nbytes, "pack_s": 0.0,
            "unpack_s": 0.0, "start_s": self.now,
            "src": self._edge_ids[ei]}
        self._push(self.now, self._ids[s], K_PACKED, s)

    def _on_packed(self, s: int) -> None:
        mig = self._inflight_mig.pop(s)
        src = self.edges[mig["src"]]
        _, done, wait = src.reserve_backhaul(self.now, mig["nbytes"])
        mig["queue_s"] = wait
        dst_shard = self.shard_of_edge[mig["dst"]]
        if dst_shard == self.shard_id:
            self._inflight_mig[s] = mig
            self._push(done, self._ids[s], K_XFER_MIG, s)
        else:
            # the client leaves this shard; its timing state rides along
            cid = self._ids[s]
            state = self._materialize(s)
            self._present[s] = False
            del self._slot_of_id[cid]
            self._moves.pop(s, None)
            self._dropout.pop(s, None)
            self._order_dirty = True
            self.out_mail.append(Mail(
                dst_shard=dst_shard, time=done,
                kind=EventKind.TRANSFER_DONE, key=cid,
                payload={"client": cid, "what": "migration",
                         "client_state": state, "mig": mig}))

    def _on_transfer_mig(self, s: int) -> None:
        mig = self._inflight_mig.pop(s)
        ei = self._eidx[mig["dst"]]
        dst = self._edge_list[ei]
        dst.attached += 1
        dst.migrations_in += 1
        self._edge[s] = ei
        self._edge_np[s] = ei
        self._price_slot(s)
        self._fixed_np[s] = self._fixed[s]
        self._srv_np[s] = self._srv[s]
        self._downlink_np[s] = self._downlink[s]
        self._train_resume(ei)
        end = self.now + mig["unpack_s"]
        self.out_migrations.append((
            self._ids[s], mig["src"], mig["dst"], self._epoch[s],
            mig["start_s"], end, mig["nbytes"], mig["pack_s"],
            mig.get("queue_s", 0.0),
            self.now - mig["start_s"] - mig["pack_s"]
            - mig.get("queue_s", 0.0)))
        # FedFly: resume the interrupted epoch, never restart (move_at
        # is clamped below num_batches, so batches always remain)
        assert self._batch_idx[s] < self._nb[s]
        self._begin_batch(s, end)

    # -- update arrival --------------------------------------------------

    def _on_transfer_update(self, s: int) -> None:
        now = self.now
        self.out_contribs.append((
            now, self._ids[s], self._ckeys[self._cohort[s]],
            self._replica[s], self._epoch[s], self._epoch_start_s[s],
            self._pulled_s[s], self._num_samples[s]))
        self._epochs_done[s] += 1
        if self.mode == "async":
            if self._epochs_done[s] < self.num_rounds:
                self._start_epoch(s, self._epoch[s] + 1,
                                  now + self._downlink[s])
            else:
                self._done[s] = True
                self._done_np[s] = True
