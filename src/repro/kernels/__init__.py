"""Pallas TPU kernels for the perf-critical compute layers.

flash_attention — blocked causal/sliding-window GQA attention
wkv6            — RWKV6 chunked data-dependent-decay recurrence
fedavg_agg      — streaming weighted parameter aggregation (FedAvg)
int8_codec      — blockwise int8 quantize/dequantize (migration payloads)

Each package: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper with jnp fallback), ref.py (pure-jnp oracle). All validated in
interpret=True mode on CPU; the TPU path is the same kernel compiled.
"""
