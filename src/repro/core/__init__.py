"""FedFly core: split training, FedAvg, checkpointing, migration,
mobility traces, and the synchronous round scheduler.

Submodules load lazily (PEP 562): most of them import JAX, and an
eager package ``__init__`` would drag the toolchain into every process
that merely touches ``repro.core`` on the way to a JAX-free leaf —
including the spawned shard workers that must stay lightweight. Lazy
loading also dissolves the old ``repro.runtime.cluster`` <->
``repro.core.scheduler`` import-order trap: nothing imports scheduler
until someone asks for it.
"""
from __future__ import annotations

import importlib

_SUBMODULES = ("checkpoint", "fedavg", "migration", "mobility",
               "scheduler", "serve_migration", "split")

__all__ = list(_SUBMODULES)


def __getattr__(name: str):
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.core.{name}")
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_SUBMODULES))
