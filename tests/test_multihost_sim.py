"""Multi-host sharded simulation over localhost TCP: bit-identity with
the in-process SerialExecutor across host counts, validation of the
hosts= contract, and the killed-host abort."""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.core.mobility import MobilityTrace, poisson_moves
from repro.models.vgg import VGG5
from repro.optim.optimizers import sgd
from repro.optim.schedules import constant
from repro.sim.edge import make_edges
from repro.sim.fleet import Fleet, make_fleet_specs
from repro.sim.mailbox import HostShardedEngine
from repro.sim.simulator import FleetSimulator


def flat_params(tree):
    return np.concatenate([np.asarray(x).ravel()
                           for x in jax.tree.leaves(tree)])


def make_sim(*, shards=4, hosts=None, num_clients=16, num_edges=4,
             rounds=3, seed=1, rate=0.3, **kw):
    edges = make_edges(num_edges, slots=8)
    specs = make_fleet_specs(num_clients, [e.edge_id for e in edges],
                             batch_size=8, num_batches=3)
    fleet = Fleet(VGG5(), sgd(momentum=0.9), specs, split_point=2,
                  lr_schedule=constant(0.01), max_replicas=4, seed=seed)
    trace = MobilityTrace(poisson_moves([s.client_id for s in specs],
                                        [e.edge_id for e in edges],
                                        rounds, rate, seed=seed))
    return FleetSimulator(fleet, edges, mode=kw.pop("mode", "async"),
                          shards=shards, hosts=hosts, trace=trace,
                          measure_pack=kw.pop("measure_pack", False), **kw)


@pytest.mark.slow
def test_host_count_invariance():
    """1 vs 2 vs 4 socket hosts on localhost: per-round metrics, final
    params, migration summary, and per-edge stats all bit-identical to
    the in-process SerialExecutor — the transport never touches the
    simulation."""
    base = make_sim().run(3)                       # SerialExecutor
    assert base.migration_summary["count"] > 0     # migrations do cross
    for hosts in (1, 2, 4):
        other = make_sim(hosts=hosts).run(3)
        assert other.engine_stats["num_hosts"] == hosts
        assert other.rounds == base.rounds
        assert other.migration_summary == base.migration_summary
        assert other.edge_stats == base.edge_stats
        assert (flat_params(other.final_params)
                == flat_params(base.final_params)).all()


def test_hosts_validation():
    with pytest.raises(ValueError, match="measure_pack=False"):
        make_sim(hosts=2, measure_pack=True)
    with pytest.raises(ValueError, match="mutually exclusive"):
        make_sim(hosts=2, workers=2)
    with pytest.raises(ValueError, match="hosts must be"):
        make_sim(hosts=0)


@pytest.mark.slow
def test_sync_multihost_matches_serial():
    """Sync mode over socket hosts (the control-mail round restart):
    bit-identical to the serial sync run, with cohort training running
    in the host processes."""
    base = make_sim(mode="sync").run(3)
    other = make_sim(mode="sync", hosts=2).run(3)
    assert other.rounds == base.rounds
    assert other.migration_summary == base.migration_summary
    assert other.edge_stats == base.edge_stats
    assert (flat_params(other.final_params)
            == flat_params(base.final_params)).all()
    trainers = other.engine_stats["trainers"]
    assert sum(t["epochs_trained"] for t in trainers.values()) > 0
    import os
    assert all(t["pid"] != os.getpid() for t in trainers.values())


def test_hosts_clamped_to_shards():
    sim = make_sim(shards=2, hosts=8)
    assert sim.hosts == 2


def test_run_multihost_rejects_gapped_directory():
    """A directory whose ranks are not exactly 0..H-1 would orphan the
    missing rank's shards and drop their mail — reject it up front."""
    sim = make_sim()
    with pytest.raises(ValueError, match="0..1"):
        sim.run_multihost(1, rank=0, listen=("127.0.0.1", 0),
                          addresses={0: ("127.0.0.1", 1), 2: ("127.0.0.1", 2)})


@pytest.mark.slow
def test_killed_host_process_aborts_run():
    """A host process killed after the mesh handshake must abort the
    coordinator's run with a clear error (via the surviving hosts'
    disconnect aborts and/or the dead host's record-stream close) —
    never hang the window barrier."""
    sim = make_sim()
    shards = sim._build_shards(3)
    for s in shards:
        s.bootstrap_async()
    engine = HostShardedEngine(shards, lookahead=sim._lookahead(), hosts=2)
    try:
        engine._procs[1].kill()
        with pytest.raises(RuntimeError,
                           match="died|disconnected|failed"):
            engine.run(lambda *a: None)
    finally:
        engine.close()
