"""Sharded fleet simulation: shard-count invariance, worker-process
parity, congestion re-pricing, empty-round robustness, batched async
mixing equivalence."""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.core.mobility import MobilityTrace, MoveEvent, poisson_moves
from repro.models.vgg import VGG5
from repro.optim.optimizers import sgd
from repro.optim.schedules import constant
from repro.runtime.cluster import HardwareProfile
from repro.sim.async_agg import AsyncAggregator, SyncAggregator
from repro.sim.edge import make_edges
from repro.sim.fleet import ClientSpec, Fleet
from repro.sim.metrics import FleetMetrics
from repro.sim.shard import InflightBatch
from repro.sim.simulator import FleetSimulator


def flat_params(tree):
    return np.concatenate([np.asarray(x).ravel()
                           for x in jax.tree.leaves(tree)])


def make_sharded(mode, shards, *, workers=None, num_clients=16,
                 num_edges=4, seed=1, rate=0.3, rounds=3, **kw):
    edges = make_edges(num_edges, slots=8)
    from repro.sim.fleet import make_fleet_specs
    specs = make_fleet_specs(num_clients, [e.edge_id for e in edges],
                             batch_size=8, num_batches=3)
    fleet = Fleet(VGG5(), sgd(momentum=0.9), specs, split_point=2,
                  lr_schedule=constant(0.01), max_replicas=4, seed=seed)
    trace = MobilityTrace(poisson_moves([s.client_id for s in specs],
                                        [e.edge_id for e in edges],
                                        rounds, rate, seed=seed))
    return FleetSimulator(fleet, edges, mode=mode, shards=shards,
                          workers=workers, trace=trace,
                          measure_pack=False, **kw)


# -- shard-count invariance --------------------------------------------------

@pytest.mark.parametrize("mode", ["sync", "async"])
def test_shard_count_invariance(mode):
    """Same seed, 1 vs 2 vs 4 shards: per-round metrics bit-identical,
    final global params bit-identical, per-edge stats identical."""
    base = make_sharded(mode, 1).run(3)
    assert base.migration_summary["count"] > 0    # migrations do cross
    for k in (2, 4):
        other = make_sharded(mode, k).run(3)
        assert other.rounds == base.rounds
        assert other.migration_summary == base.migration_summary
        assert other.edge_stats == base.edge_stats
        assert (flat_params(other.final_params)
                == flat_params(base.final_params)).all()

        def protocol_events(stats):
            # ROUND_START is a per-shard control event, one per shard per
            # round — everything else must match exactly
            return {k: v for k, v in stats["by_kind"].items()
                    if k != "round_start"}
        assert protocol_events(other.engine_stats) == \
            protocol_events(base.engine_stats)


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["sync", "async"])
def test_worker_processes_match_serial(mode):
    """The multiprocessing shard executors (windowed for sync, peer mesh
    for async) must be bit-identical to in-process shards."""
    serial = make_sharded(mode, 2, num_clients=8, rounds=2).run(2)
    mp_run = make_sharded(mode, 2, workers=2, num_clients=8,
                          rounds=2).run(2)
    assert mp_run.rounds == serial.rounds
    assert mp_run.migration_summary == serial.migration_summary
    assert (flat_params(mp_run.final_params)
            == flat_params(serial.final_params)).all()


def test_workers_require_skipping_real_pack():
    edges = make_edges(2)
    from repro.sim.fleet import make_fleet_specs
    specs = make_fleet_specs(4, [e.edge_id for e in edges])
    fleet = Fleet(VGG5(), sgd(momentum=0.9), specs, split_point=2,
                  lr_schedule=constant(0.01), max_replicas=2, seed=0)
    with pytest.raises(ValueError):
        FleetSimulator(fleet, edges, shards=2, workers=2,
                       measure_pack=True)


# -- congestion re-pricing ----------------------------------------------------

def test_inflight_batch_reprice_math():
    """Constant congestion reduces exactly to fixed + srv * g; a
    mid-flight change re-prices only the remaining server work."""
    fb = InflightBatch(client_id="c", fixed_s=1.0, srv_s=2.0,
                       remaining=3.0, last_t=0.0, cong=1.0)
    assert fb.reprice(0.0, 1.0) == pytest.approx(3.0)      # 1 + 2*1
    fb2 = InflightBatch(client_id="c", fixed_s=1.0, srv_s=2.0,
                        remaining=3.0, last_t=0.0, cong=1.0)
    assert fb2.reprice(0.0, 2.0) == pytest.approx(5.0)     # 1 + 2*2
    # halfway through (1.5 base-seconds consumed at g=1), double the load:
    # remaining 1.5 base-s now progress at rate 3/5 -> 2.5 s more
    fb3 = InflightBatch(client_id="c", fixed_s=1.0, srv_s=2.0,
                        remaining=3.0, last_t=0.0, cong=1.0)
    assert fb3.reprice(1.5, 2.0) == pytest.approx(1.5 + 1.5 / (3.0 / 5.0))


def _two_edge_fleet(trace, *, shards=1, reprice_tol=0.05):
    """Client A alone on a weak 1-slot edge-1; B on edge-0. One batch per
    epoch, so only *in-flight* re-pricing can slow A down."""
    edges = make_edges(2, slots=1,
                       profiles=(HardwareProfile("edge-tiny", 1.5e9),))
    specs = [ClientSpec(client_id="dev-A", profile=edges[0].profile,
                        edge_id="edge-1", batch_size=8, num_batches=1),
             ClientSpec(client_id="dev-B", profile=edges[0].profile,
                        edge_id="edge-0", batch_size=8, num_batches=1)]
    fleet = Fleet(VGG5(), sgd(momentum=0.9), specs, split_point=2,
                  lr_schedule=constant(0.01), max_replicas=2, seed=0)
    return FleetSimulator(fleet, edges, mode="sync", trace=trace,
                          measure_pack=False, shards=shards,
                          reprice_tol=reprice_tol)


def dur(res, cid, r=0):
    return next(c.duration_s for c in res.metrics.contributions
                if c.client_id == cid and c.round_idx == r)


def test_migrant_landing_mid_batch_repriced():
    """Regression for schedule-time-only congestion pricing: a client
    migrating onto a busy 1-slot edge mid-batch must stretch the
    resident's in-flight batch (num_batches=1, so no later batch could
    absorb the slowdown under the old model)."""
    quiet = _two_edge_fleet(None).run(1)
    trace = MobilityTrace([MoveEvent(0, "dev-B", "edge-0", "edge-1", 0.0)])
    crowded = _two_edge_fleet(trace).run(1)
    assert crowded.migration_summary["count"] == 1
    # the resident pays for the processor sharing it didn't have at
    # schedule time
    assert dur(crowded, "dev-A") > dur(quiet, "dev-A") * 1.05
    # and the re-priced run is still shard-count invariant
    crowded2 = _two_edge_fleet(trace, shards=2).run(1)
    assert crowded2.rounds == crowded.rounds
    assert dur(crowded2, "dev-A") == dur(crowded, "dev-A")


def test_reprice_tol_zero_is_at_least_as_slow():
    """Exact repricing (tol=0) can only make the crowded resident slower
    or equal vs the default tolerance band."""
    trace = MobilityTrace([MoveEvent(0, "dev-B", "edge-0", "edge-1", 0.0)])
    tol = _two_edge_fleet(trace).run(1)
    exact = _two_edge_fleet(trace, reprice_tol=0.0).run(1)
    assert dur(exact, "dev-A") >= dur(tol, "dev-A") - 1e-9


# -- empty sync round ---------------------------------------------------------

def test_empty_round_commit_carries_forward():
    """Regression: SyncAggregator.commit() used to crash on fedavg's
    non-empty assertion when every client was mid-migration/offline."""
    init = {"w": np.full((4,), 3.0, np.float32)}
    agg = SyncAggregator(init)
    out = agg.commit()                            # nothing submitted
    np.testing.assert_array_equal(out["w"], init["w"])
    assert agg.version == 1 and agg.skipped_rounds == 1
    agg.submit({"w": np.ones((4,), np.float32)}, weight=2.0)
    out = agg.commit()                            # normal rounds still work
    np.testing.assert_allclose(out["w"], 1.0)
    assert agg.version == 2 and agg.skipped_rounds == 1


def test_skipped_round_metric_record():
    m = FleetMetrics()
    m.record_skipped_round(0, 12.5)
    m.record_contribution(client_id="c", round_idx=1, arrival_s=20.0,
                          duration_s=1.0, staleness=0, loss=1.0,
                          mix_weight=0.0)
    rounds = m.build_rounds()
    assert rounds[0] == {"round_idx": 0, "n_updates": 0,
                         "skipped_round": True, "barrier_s": 12.5,
                         "n_migrations": 0}
    assert rounds[1]["round_idx"] == 1 and rounds[1]["n_updates"] == 1


# -- batched async mixing -----------------------------------------------------

def test_flush_batch_equals_sequential_submits():
    """One fedavg_agg_mix dispatch == the same updates submitted one by
    one (within fp tolerance), including the weight EMA and staleness
    discounts, and version/total_weight bookkeeping."""
    rng = np.random.default_rng(7)
    init = {"w": rng.normal(size=(300,)).astype(np.float32),
            "b": rng.normal(size=(41,)).astype(np.float32)}
    updates = [({"w": rng.normal(size=(300,)).astype(np.float32),
                 "b": rng.normal(size=(41,)).astype(np.float32)},
                float(rng.uniform(100, 900)), int(rng.integers(0, 6)))
               for _ in range(17)]
    seq = AsyncAggregator(init, alpha=0.4)
    for tree, w, s in updates:
        seq.submit(tree, weight=w, staleness=s)
    bat = AsyncAggregator(init, alpha=0.4)
    alphas = bat.flush_batch(updates)
    assert bat.version == seq.version == 17
    assert bat.total_weight_applied == pytest.approx(
        seq.total_weight_applied, rel=1e-6)
    assert len(alphas) == 17 and all(0.0 <= a <= 1.0 for a in alphas)
    np.testing.assert_allclose(bat.params["w"], seq.params["w"], atol=2e-5)
    np.testing.assert_allclose(bat.params["b"], seq.params["b"], atol=2e-5)


def test_flush_batch_groups_shared_trees():
    """Clients sharing a cohort replica share a tree object; the stacked
    axis must collapse to distinct trees without changing the math."""
    init = {"w": np.zeros((64,), np.float32)}
    shared = {"w": np.ones((64,), np.float32)}
    updates = [(shared, 100.0, 0)] * 5
    seq = AsyncAggregator(init, alpha=0.2)
    for tree, w, s in updates:
        seq.submit(tree, weight=w, staleness=s)
    bat = AsyncAggregator(init, alpha=0.2)
    bat.flush_batch(updates)
    np.testing.assert_allclose(bat.params["w"], seq.params["w"], atol=1e-6)


def test_sync_snapshots_pruned_each_round():
    """Regression: sync-mode pruning counted deduped replicas against
    the per-cohort *client* count, so the floor never advanced and every
    round's snapshots accumulated for the whole run."""
    sim = make_sharded("sync", 1, rate=0.0)
    sim.run(3)
    for cohort in sim.fleet.cohorts.values():
        assert len(cohort.snapshots) <= 1       # old epochs pruned


@pytest.mark.slow
def test_shard_sweep_cli_small_fleet(tmp_path):
    """Regression: the sweep used to mix measure_pack settings between
    shard counts at <=128 clients, tripping its own bit-identity check."""
    import json
    from benchmarks.bench_fleet import main
    artifact = tmp_path / "sweep.json"
    main(["--quick", "--shard-sweep", "1", "2", "--scenarios", "poisson",
          "--artifact", str(artifact)])
    sweep = json.loads(artifact.read_text())
    assert sweep["per_shards"]["2"]["rounds_bit_identical"] is True


def test_flush_interval_is_reproducible():
    """Explicit flush_interval_s overrides the auto grid and still gives
    deterministic, shard-invariant results."""
    a = make_sharded("async", 1, num_clients=8, rounds=2,
                     flush_interval_s=0.05).run(2)
    b = make_sharded("async", 4, num_clients=8, rounds=2,
                     flush_interval_s=0.05).run(2)
    assert a.rounds == b.rounds
    assert (flat_params(a.final_params) == flat_params(b.final_params)).all()
