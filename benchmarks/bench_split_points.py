"""Paper Fig. 3(c): vary the split point SP1/SP2/SP3 (conv units kept on
the device), mobile device with 25% of the data moving at 90% of
training. Reports per-round time for FedFly vs SplitFed and the
checkpoint transfer time at each SP (paper: "still up to two seconds").
"""
from __future__ import annotations

import argparse

from benchmarks.common import make_batchers, make_scheduler
from repro.core.mobility import MobilityTrace, move_at_round
from repro.models.vgg import SPLIT_POINTS

MOBILE = "pi3_1"


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n-train", type=int, default=4000)
    args = ap.parse_args(argv)

    print("# Fig3c: split-point sweep (mobile 25% data, move at 90% of "
          "the round)")
    print(f"{'SP':>4s} {'fedfly':>8s} {'splitfed':>9s} {'reduction':>9s} "
          f"{'ckpt MB':>8s} {'transfer s':>10s}")
    for spname, spn in sorted(SPLIT_POINTS.items()):
        batchers, _ = make_batchers(args.n_train, 0.25)
        trace = MobilityTrace(move_at_round(MOBILE, "edge-A", "edge-B", 1,
                                            fraction=0.9))
        t = {}
        rep = None
        for mode in ("fedfly", "splitfed"):
            s = make_scheduler(batchers, split_point=spn)
            h = s.run(2, trace, mode=mode)
            t[mode] = h.rounds[1].client_times_sim[MOBILE]
            if mode == "fedfly":
                rep = h.rounds[1].migrations[0]
        red = 100.0 * (1 - t["fedfly"] / t["splitfed"])
        print(f"{spname:>4s} {t['fedfly']:8.2f} {t['splitfed']:9.2f} "
              f"{red:8.1f}% {rep.nbytes/1e6:8.2f} {rep.sim_total_s:10.3f}")


if __name__ == "__main__":
    main()
