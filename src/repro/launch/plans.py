"""Execution plans: per-(arch × input-shape) knobs for the production mesh.

A plan decides what the dry-run lowers:
  * dtypes       — ≥100B-param MoE archs (arctic, grok) hold params,
                   momentum and grad-accumulators in bf16 so train_4k fits
                   16 GB HBM per chip (DESIGN.md §6); everything else
                   trains params fp32 / compute bf16.
  * microbatches — grad accumulation splits train_4k's global batch so the
                   remat stash (L × rows × S × d) stays ≲2 GB per chip.
  * window_override — long_500k on pure full-attention archs runs the
                   framework's sliding-window variant (4096) per the
                   assignment carve-out; recorded in the plan's note.
  * skip         — (arch, shape) pairs that are out of scope, with reason.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.configs.base import InputShape, ModelConfig


@dataclass(frozen=True)
class ExecPlan:
    arch: str
    shape: str
    microbatches: int = 1
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    momentum_dtype: Optional[str] = None    # None = same as param dtype
    window_override: int = 0                # >0: force sliding window
    skip: bool = False
    note: str = ""
    # §Perf hillclimb levers (beyond-paper optimizations; default off so
    # the recorded baseline stays the paper-faithful generic layout):
    #   zero1       — params model-sharded only (no FSDP over data);
    #                 grads+momentum data-sharded; per-microbatch grad
    #                 reduce-scatter instead of full all-reduce; one
    #                 param gather per step (ZeRO-1).
    #   moe_ep_data — expert axis sharded over ``data`` (tokens all-to-all
    #                 to their experts), f over ``model``: expert grads
    #                 are local, no cross-data grad reduction.
    #   wkv_chunked — RWKV6 chunk-parallel closed form (matmul within
    #                 64-token chunks) instead of the token-level scan.
    opt_flags: tuple = ()


# archs whose every layer is full-causal attention (no native long-context
# path); long_500k runs only via the sliding-window variant
_FULL_ATTN = ("yi-6b", "minicpm-2b", "qwen3-0.6b", "whisper-large-v3",
              "internvl2-1b", "arctic-480b", "grok-1-314b")
_GIANT = ("arctic-480b", "grok-1-314b")    # ≥100B params: bf16 everywhere


def plan_for(cfg: ModelConfig, shape: InputShape) -> ExecPlan:
    arch = cfg.name
    kw = dict(arch=arch, shape=shape.name)

    if arch in _GIANT:
        kw.update(param_dtype="bfloat16", momentum_dtype="bfloat16")

    if shape.kind == "train":
        # one batch row per chip per microbatch keeps the remat stash
        # small; more microbatches than (global_batch / data-axis) would
        # leave data shards idle and break the batch sharding hints.
        kw["microbatches"] = 16

    if shape.name == "long_500k":
        if arch in _FULL_ATTN:
            kw.update(window_override=4096,
                      note="full-attention arch: long_500k uses the "
                           "framework sliding-window variant (assignment "
                           "carve-out); native 512k full attention skipped")
        elif arch == "gemma2-9b":
            kw["note"] = ("native local/global alternation: local layers "
                          "keep a 4096 ring, global layers the full 512k "
                          "cache")
        else:
            kw["note"] = "native sub-quadratic decode (SSM/hybrid state)"

    return ExecPlan(**kw)


def apply_plan(cfg: ModelConfig, plan: ExecPlan) -> ModelConfig:
    """Return the config the dry-run actually lowers."""
    kw = dict(param_dtype=plan.param_dtype, compute_dtype=plan.compute_dtype)
    if plan.window_override > 0:
        kw.update(sliding_window=plan.window_override, local_global_period=0)
    if "wkv_chunked" in plan.opt_flags and cfg.rwkv:
        kw["rwkv_chunked"] = True
    if "mamba_chunked" in plan.opt_flags and cfg.hybrid_attn_ssm:
        kw["mamba_chunked"] = True
    return cfg.replace(**kw)
