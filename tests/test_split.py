"""Split-point equivalence (the FedFly substrate invariant): for ANY
split point, the two-stage split training step computes EXACTLY the same
loss and gradients as the monolithic step — the chain rule across the
smashed-data boundary must be the identity transformation of training."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from conftest import batch_for
from repro.core import split as sp
from repro.models.registry import ARCH_IDS
from repro.models.vgg import VGG5, SPLIT_POINTS


def _max_err(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_split_equivalence(arch, reduced_models):
    cfg, model, params = reduced_models(arch)
    batch = batch_for(cfg)
    loss_ref, g_ref = sp.monolithic_value_and_grad(model, params, batch)
    for spn in (1,):
        dev, srv = sp.partition_params(model, params, spn)
        loss_s, g_dev, g_srv = sp.split_value_and_grad(model, dev, srv,
                                                       batch, spn)
        merged = sp.merge_grads(model, g_dev, g_srv)
        assert abs(float(loss_ref - loss_s)) < 1e-6
        assert _max_err(g_ref, merged) < 1e-5


@pytest.mark.parametrize("spname,spn", sorted(SPLIT_POINTS.items()))
def test_vgg_split_points(spname, spn):
    model = VGG5()
    params = model.init(jax.random.PRNGKey(0))
    imgs = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 32, 3))
    batch = {"images": imgs, "labels": jnp.array([0, 1, 2, 3], jnp.int32)}
    loss_ref, g_ref = jax.value_and_grad(
        lambda p: model.loss(p, batch))(params)
    dev, srv = sp.partition_params(model, params, spn)
    loss_s, g_dev, g_srv = sp.split_value_and_grad(model, dev, srv, batch,
                                                   spn)
    merged = sp.merge_grads(model, g_dev, g_srv)
    assert abs(float(loss_ref - loss_s)) < 1e-6
    assert _max_err(g_ref, merged) < 1e-5


def test_partition_merge_roundtrip(reduced_models):
    cfg, model, params = reduced_models("yi-6b")
    dev, srv = sp.partition_params(model, params, 1)
    back = sp.merge_params(model, dev, srv)
    assert _max_err(params, back) == 0.0


def test_smashed_bytes_scales_with_batch(reduced_models):
    cfg, model, params = reduced_models("qwen3-0.6b")
    dev, _ = sp.partition_params(model, params, 1)
    b1 = sp.smashed_bytes(model, dev, (2, 16), 1)
    b2 = sp.smashed_bytes(model, dev, (4, 16), 1)
    assert b2 == 2 * b1
    assert b1 == 2 * 16 * cfg.d_model * 4  # fp32 activations


def test_vgg_smashed_smaller_at_deeper_split():
    """Paper Fig 3c: deeper split points shrink the smashed payload for
    VGG-5 (pooling halves spatial dims)."""
    model = VGG5()
    params = model.init(jax.random.PRNGKey(0))
    sizes = []
    for spn in (1, 2, 3):
        dev, _ = sp.partition_params(model, params, spn)
        sizes.append(sp.smashed_bytes(model, dev, (100, 0), spn))
    assert sizes[0] > sizes[1] > sizes[2]
