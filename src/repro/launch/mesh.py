"""Production meshes and TPU hardware constants.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module never touches jax device state — only
``launch/dryrun.py`` sets XLA_FLAGS for 512 host devices.

Mesh semantics (DESIGN.md §4): the ``pod`` axis is the *edge-server* axis
of FedFly — each pod is one edge realm training its own model replica;
``data`` shards clients/batch inside a realm; ``model`` shards tensors.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """A mesh over whatever devices actually exist (CPU testbed runs)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))


# ---------------------------------------------------------------------------
# TPU v5e-like hardware constants (per assignment: the roofline targets)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TPUSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12      # FLOP/s per chip
    hbm_bandwidth: float = 819e9         # bytes/s per chip
    ici_bandwidth: float = 50e9          # bytes/s per link
    hbm_bytes: float = 16e9              # capacity per chip


TPU_V5E = TPUSpec()
