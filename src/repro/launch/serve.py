"""Serving driver: batched prefill + decode of a (reduced) arch on CPU.

Demonstrates the inference path the decode dry-run shapes lower:
prefill a batch of prompts (collecting the KV cache), then step the
decoder one token at a time with greedy sampling.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \\
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.datasets import synthetic_tokens
from repro.launch import steps as steps_lib
from repro.models.registry import build_model, get_config, make_reduced
from repro.obs import log as obs_log

log = obs_log.get_logger("launch.serve")


def build_cache_from_prefill(model, cfg, params, batch, prompt_len: int,
                             total_len: int):
    """Run prefill, then seed a decode cache with the collected K/V."""
    B = batch["tokens"].shape[0]
    prefill = steps_lib.make_prefill_step(model)
    logits, aux = jax.jit(prefill)(params, batch)
    cache = model.init_cache(B, total_len)
    if cfg.rwkv:
        cache["rwkv_state"] = aux["rwkv_state"]
        cache["rwkv_xprev"] = aux["rwkv_xprev"]
        cache["cmix_xprev"] = aux["cmix_xprev"]
        return logits, cache
    C = cache["k"].shape[2]
    S = min(prompt_len, C)
    k, v = aux["k"], aux["v"]          # (L, B, S_p, KV, hd)
    cache["k"] = cache["k"].at[:, :, :S].set(
        k[:, :, -S:].astype(cache["k"].dtype))
    cache["v"] = cache["v"].at[:, :, :S].set(
        v[:, :, -S:].astype(cache["v"].dtype))
    pos = jnp.broadcast_to(jnp.arange(prompt_len - S, prompt_len,
                                      dtype=jnp.int32),
                           cache["pos_tab"].shape[:2] + (S,))
    cache["pos_tab"] = cache["pos_tab"].at[:, :, :S].set(pos)
    if cfg.hybrid_attn_ssm:
        cache["ssm_state"] = aux["ssm_state"]
    return logits, cache


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    obs_log.add_verbosity_flags(ap)
    args = ap.parse_args()
    obs_log.setup(verbosity=obs_log.verbosity_from_args(args))

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    B, S = args.batch, args.prompt_len
    batch = {"tokens": jnp.asarray(
        synthetic_tokens(B, S, cfg.vocab_size, args.seed)["tokens"])}
    if cfg.vision_prefix:
        batch["vision_embeds"] = jnp.zeros((B, cfg.vision_prefix,
                                            cfg.d_model), jnp.float32)
    if cfg.encoder_layers:
        batch["frames"] = jnp.zeros((B, cfg.encoder_seq, cfg.d_model),
                                    jnp.float32)

    total = S + args.gen
    t0 = time.perf_counter()
    logits, cache = build_cache_from_prefill(model, cfg, params, batch, S,
                                             total)
    log.info("prefill: %dx%d tokens in %.2fs",
             B, S, time.perf_counter() - t0)

    serve = jax.jit(steps_lib.make_serve_step(model))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, cache = serve(params, cache, tok, jnp.int32(S + i))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    dt = time.perf_counter() - t0
    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    log.info("decode: %d steps x %d seqs in %.2fs (%.1f tok/s)",
             args.gen - 1, B, dt, (args.gen - 1) * B / max(dt, 1e-9))
    for b in range(min(B, 2)):
        log.info("  seq%d: %s", b, gen[b].tolist())
    assert np.isfinite(np.asarray(logits)).all(), "non-finite logits"
    log.info("ok")


if __name__ == "__main__":
    main()
